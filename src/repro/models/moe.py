"""Mixture-of-Experts FFN: top-k routing, capacity-bucketed dispatch.

GShard-style routing without the (tokens, E, capacity) one-hot blow-up:
position-in-expert comes from a cumsum over a (tokens·k, E) one-hot, tokens
are scatter-added into per-expert (E, C, D) buffers (expert dim sharded over
the model axis = expert parallelism; SPMD inserts the all-to-alls), experts
run as one batched einsum, and results gather back with router weights.

Tokens beyond an expert's capacity are dropped (standard switch behavior);
the auxiliary load-balance loss keeps the drop rate low.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import constrain, dense, pdtype

__all__ = ["init_moe", "moe_ffn", "expert_capacity"]


def init_moe(key, cfg: ModelConfig, n_layers: int):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    ks = jax.random.split(key, 4)
    dt = pdtype(cfg)
    return {
        "router": jax.random.normal(ks[0], (n_layers, d, e), jnp.float32) / np.sqrt(d),
        "w_gate": jax.random.normal(ks[1], (n_layers, e, d, f), dt) / np.sqrt(d),
        "w_up": jax.random.normal(ks[2], (n_layers, e, d, f), dt) / np.sqrt(d),
        "w_down": jax.random.normal(ks[3], (n_layers, e, f, d), dt) / np.sqrt(f),
    }


def expert_capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = int(np.ceil(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor))
    return max(8, -(-c // 8) * 8)  # round up to 8


def moe_ffn(p: dict, x: jnp.ndarray, cfg: ModelConfig):
    """x (B, S, D) -> (y (B, S, D), aux_loss scalar)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    cap = expert_capacity(t, cfg)
    xt = constrain(x.reshape(t, d), ("dp", None))

    logits = (xt @ p["router"].astype(xt.dtype)).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)  # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # position-in-expert via cumsum over flattened (T·k) choices, k-major so
    # first choices win capacity slots
    idx_f = idx.T.reshape(-1)  # (k·T,) choice-major
    onehot = jax.nn.one_hot(idx_f, e, dtype=jnp.float32)  # (kT, E)
    pos_f = (jnp.cumsum(onehot, axis=0) - 1.0)  # running count per expert
    pos_f = jnp.take_along_axis(pos_f, idx_f[:, None], axis=1)[:, 0]  # (kT,)
    keep = pos_f < cap
    slot = jnp.where(keep, pos_f, cap).astype(jnp.int32)  # overflow -> slot `cap`

    # dispatch: scatter tokens into (E, C+1, D); slot `cap` is the trash row
    xt_rep = jnp.tile(xt, (k, 1))  # (kT, D) choice-major
    buf = jnp.zeros((e, cap + 1, d), xt.dtype)
    buf = buf.at[idx_f, slot].add(xt_rep)
    buf = constrain(buf[:, :cap, :], ("tp", None, None))  # expert parallelism

    # expert FFN (SwiGLU), batched over experts
    cim = cfg.cim
    if cim is not None and cim.mode != "exact":
        # CiM path: per-expert quantized matmuls (vmapped over E)
        from repro.core.cim_linear import cim_matmul

        mm = jax.vmap(lambda xb, wb: cim_matmul(xb, wb, cim))
        bf32 = buf.astype(jnp.float32)
        h = jax.nn.silu(mm(bf32, p["w_gate"].astype(jnp.float32))) * mm(
            bf32, p["w_up"].astype(jnp.float32)
        )
        out = mm(h, p["w_down"].astype(jnp.float32)).astype(buf.dtype)
    else:
        h = jax.nn.silu(
            jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(buf.dtype))
        ) * jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(buf.dtype))
        out = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(h.dtype))  # (E, C, D)

    # combine: gather back, apply gates, drop overflowed
    out_pad = jnp.pad(out, ((0, 0), (0, 1), (0, 0)))  # restore trash row
    y_f = out_pad[idx_f, slot]  # (kT, D)
    gate_f = gate.T.reshape(-1) * keep.astype(jnp.float32)
    y = (y_f.astype(jnp.float32) * gate_f[:, None]).reshape(k, t, d).sum(0)
    y = constrain(y, ("dp", None))

    # switch-style load-balance aux loss
    frac_tokens = jnp.mean(jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac_tokens * frac_probs)

    return y.astype(x.dtype).reshape(b, s, d), aux


def moe_ffn_dense(p: dict, x: jnp.ndarray, cfg: ModelConfig):
    """Dense-masked expert compute — the collective-minimal MoE layout.

    Every device computes its LOCAL experts (E sharded over the model axis)
    on its LOCAL tokens (batch sharded over data): zero dispatch traffic; the
    only communication is the final psum over the model axis when the
    expert-weighted outputs combine. Trades ~E_local/top_k extra expert FLOPs
    for the elimination of the scatter/all-to-all dispatch — a large win when
    per-expert FFNs are small (qwen3-moe: 768 wide). No capacity drops.
    (Perf iteration B1, EXPERIMENTS.md §Perf.)
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    xt = constrain(x.reshape(t, d), ("dp", None))

    logits = (xt @ p["router"].astype(xt.dtype)).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    # (T, E) routing weights, zero off the top-k (small scatter: T x E floats)
    w_te = jnp.zeros((t, e), jnp.float32)
    w_te = w_te.at[jnp.arange(t)[:, None], idx].add(gate)
    w_te = constrain(w_te.astype(x.dtype), ("dp", None))

    # local experts on local tokens: (E, T, F) sharded (tp, dp, -)
    hg = jnp.einsum("td,edf->etf", xt, p["w_gate"].astype(xt.dtype))
    hu = jnp.einsum("td,edf->etf", xt, p["w_up"].astype(xt.dtype))
    h = constrain(jax.nn.silu(hg) * hu, ("tp", "dp", None))
    # fold routing weights in BEFORE the down projection so the (E, T, D)
    # intermediate never materializes; contraction over (e, f) psums over tp
    hw = h * w_te.T[:, :, None]
    y = jnp.einsum("etf,efd->td", hw, p["w_down"].astype(h.dtype))
    y = constrain(y, ("dp", None))

    frac_tokens = jnp.mean(jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return y.astype(x.dtype).reshape(b, s, d), aux
