"""Mamba2 (SSD — state-space duality) blocks, chunked + single-step decode.

The chunked SSD form (arXiv:2405.21060) is TPU/MXU-friendly: the sequence is
split into chunks; the intra-chunk term is a masked matmul ("attention-like"),
and the inter-chunk term is a short ``lax.scan`` over chunk states — no
per-token recurrence. Decode uses the O(1) state recurrence.

Projection components are stored as SEPARATE leaves (z/x/B/C/dt and per-stream
conv kernels) so tensor parallelism can shard the head-aligned dims cleanly
(heads over the model axis when divisible; see launch/shardings.py). This is
the TP layout real Mamba2 deployments use.

Parameter layout per stacked layer dim L (G=1 SSM group):
  in_z, in_x   (L, D, d_inner)
  in_b, in_c   (L, D, N)
  in_dt        (L, D, H)
  conv_{x,b,c} (L, W, d_inner | N | N) + conv_{x,b,c}_bias
  A_log, D, dt_bias (L, H)
  norm (L, d_inner);  out_proj (L, d_inner, D)
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig
from repro.models.layers import axis_size, cdtype, constrain, dense, pdtype, rms_norm

__all__ = ["init_mamba", "mamba_forward", "mamba_decode_step", "make_mamba_state"]


def _dims(cfg: ModelConfig):
    di = cfg.d_inner
    h = cfg.ssm_heads
    n = cfg.ssm_state
    return di, h, n


def init_mamba(key, cfg: ModelConfig, n_layers: int):
    d = cfg.d_model
    di, h, n = _dims(cfg)
    w = cfg.ssm_conv_width
    ks = jax.random.split(key, 10)
    dt = pdtype(cfg)
    u = jax.random.uniform(ks[0], (n_layers, h), minval=1e-3, maxval=1e-1)
    nrm = lambda k, shape, fan: jax.random.normal(k, shape, dt) / np.sqrt(fan)
    return {
        "in_z": nrm(ks[1], (n_layers, d, di), d),
        "in_x": nrm(ks[2], (n_layers, d, di), d),
        "in_b": nrm(ks[3], (n_layers, d, n), d),
        "in_c": nrm(ks[4], (n_layers, d, n), d),
        "in_dt": nrm(ks[5], (n_layers, d, h), d),
        "conv_x": nrm(ks[6], (n_layers, w, di), w),
        "conv_b": nrm(ks[7], (n_layers, w, n), w),
        "conv_c": nrm(ks[8], (n_layers, w, n), w),
        "conv_x_bias": jnp.zeros((n_layers, di), dt),
        "conv_b_bias": jnp.zeros((n_layers, n), dt),
        "conv_c_bias": jnp.zeros((n_layers, n), dt),
        "A_log": jnp.log(
            jax.random.uniform(ks[9], (n_layers, h), minval=1.0, maxval=16.0)
        ).astype(jnp.float32),
        "D": jnp.ones((n_layers, h), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(u)).astype(jnp.float32),
        "norm": jnp.zeros((n_layers, di), dt),
        "out_proj": nrm(ks[0], (n_layers, di, d), di),
    }


def _causal_depthwise_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray):
    """x (B, S, C), w (W, C): causal conv as W shifted adds (HLO-compact)."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    s = x.shape[1]
    y = sum(xp[:, i : i + s, :] * w[i] for i in range(width))
    return y + b


def mamba_forward(
    p: dict,
    x: jnp.ndarray,  # (B, S, D)
    cfg: ModelConfig,
    state: Optional[dict] = None,  # populated at prefill end when serving
):
    """Full-sequence SSD. Returns (y, final_state or None)."""
    bsz, s_orig, d = x.shape
    di, h, n = _dims(cfg)
    ph = cfg.ssm_headdim
    q = min(cfg.ssm_chunk, s_orig)
    pad = (-s_orig) % q
    if pad:  # pad the sequence; padded steps get dt=0 (state frozen)
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    s = s_orig + pad
    nc = s // q
    seq_mask = (jnp.arange(s) < s_orig).astype(jnp.float32)

    cim = cfg.cim
    tp_ok = h % max(axis_size("tp"), 1) == 0
    hsh = ("dp", None, "tp" if tp_ok else None)
    z = constrain(dense(x, p["in_z"], None, cim), hsh)
    xs = constrain(dense(x, p["in_x"], None, cim), hsh)
    b_ = constrain(dense(x, p["in_b"], None, cim), ("dp", None, None))
    c_ = constrain(dense(x, p["in_c"], None, cim), ("dp", None, None))
    dt = constrain(dense(x, p["in_dt"], None, cim), hsh)

    cw = lambda t: t.astype(x.dtype)
    xs_raw, b_raw, c_raw = xs, b_, c_
    xs = jax.nn.silu(_causal_depthwise_conv(xs, cw(p["conv_x"]), cw(p["conv_x_bias"])))
    b_ = jax.nn.silu(_causal_depthwise_conv(b_, cw(p["conv_b"]), cw(p["conv_b_bias"])))
    c_ = jax.nn.silu(_causal_depthwise_conv(c_, cw(p["conv_c"]), cw(p["conv_c_bias"])))
    xs = xs.reshape(bsz, s, h, ph)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    dt = dt * seq_mask[None, :, None]  # padded steps: no state update/decay
    a = -jnp.exp(p["A_log"])  # (H,)
    da = dt * a  # (B,S,H)

    # chunked SSD in f32
    xf = xs.astype(jnp.float32).reshape(bsz, nc, q, h, ph)
    bf = b_.astype(jnp.float32).reshape(bsz, nc, q, n)
    cf = c_.astype(jnp.float32).reshape(bsz, nc, q, n)
    dtc = dt.reshape(bsz, nc, q, h)
    dac = da.reshape(bsz, nc, q, h)
    da_cs = jnp.cumsum(dac, axis=2)  # (B,NC,Q,H)

    # intra-chunk: Y[q] = sum_{k<=q} C_q·B_k * exp(cs_q - cs_k) * dt_k * x_k
    att = jnp.einsum("bcqn,bckn->bcqk", cf, bf)  # (B,NC,Q,Q)
    seg = da_cs[:, :, :, None, :] - da_cs[:, :, None, :, :]  # (B,NC,Q,K,H)
    causal = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.exp(jnp.where(causal[None, None, :, :, None], seg, -jnp.inf))
    w_qk = att[..., None] * decay * dtc[:, :, None, :, :]  # (B,NC,Q,K,H)
    y = jnp.einsum("bcqkh,bckhp->bcqhp", w_qk, xf)

    # chunk states: S_c = sum_k B_k ⊗ x_k * dt_k * exp(cs_last - cs_k)
    decay_out = jnp.exp(da_cs[:, :, -1:, :] - da_cs)  # (B,NC,Q,H)
    sterm = jnp.einsum("bckn,bckh,bckhp->bchpn", bf, dtc * decay_out, xf)

    chunk_decay = jnp.exp(da_cs[:, :, -1, :])  # (B,NC,H)

    def scan_chunks(carry, xs_):
        s_prev = carry  # (B,H,P,N)
        sterm_c, cdec = xs_
        s_new = s_prev * cdec[:, :, None, None] + sterm_c
        return s_new, s_prev

    init = (
        state["ssm"].astype(jnp.float32)
        if state is not None and "ssm" in state
        else jnp.zeros((bsz, h, ph, n), jnp.float32)
    )
    s_last, s_prevs = lax.scan(
        scan_chunks,
        init,
        (sterm.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4)  # (B,NC,H,P,N)

    # inter-chunk: Y_off[q] = C_q · S_prev * exp(cs_q)
    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", cf, s_prevs, jnp.exp(da_cs))
    y = (y + y_off).reshape(bsz, s, h, ph)
    y = y + p["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(bsz, s, di).astype(x.dtype)[:, :s_orig]

    # gated RMSNorm + out proj
    y = rms_norm(y * jax.nn.silu(z[:, :s_orig]), p["norm"], cfg.norm_eps)
    out = constrain(dense(y, p["out_proj"], None, cim), ("dp", None, None))

    new_state = None
    if state is not None:
        w1 = cfg.ssm_conv_width - 1
        tail = lambda t: jnp.pad(
            t[:, :s_orig], ((0, 0), (max(0, w1 - s_orig), 0), (0, 0))
        )[:, -w1:, :]
        new_state = {
            "ssm": s_last.astype(jnp.float32),
            "conv_x": tail(xs_raw),
            "conv_b": tail(b_raw),
            "conv_c": tail(c_raw),
        }
    return out, new_state


def mamba_decode_step(
    p: dict,
    x: jnp.ndarray,  # (B, 1, D)
    cfg: ModelConfig,
    state: dict,  # {"ssm": (B,H,P,N) f32, "conv_{x,b,c}": (B, W-1, ·)}
):
    bsz = x.shape[0]
    di, h, n = _dims(cfg)
    ph = cfg.ssm_headdim
    cim = cfg.cim

    x0 = x[:, 0, :]
    z = dense(x0, p["in_z"], None, cim)
    xs = dense(x0, p["in_x"], None, cim)
    b_ = dense(x0, p["in_b"], None, cim)
    c_ = dense(x0, p["in_c"], None, cim)
    dt = dense(x0, p["in_dt"], None, cim)

    def conv_step(prev, new, w, b):  # prev (B,W-1,C), new (B,C)
        win = jnp.concatenate([prev, new[:, None, :]], axis=1)
        out = jax.nn.silu(
            jnp.einsum("bwc,wc->bc", win.astype(jnp.float32), w.astype(jnp.float32))
            + b.astype(jnp.float32)
        )
        return out, win[:, 1:, :]

    xs_c, new_cx = conv_step(state["conv_x"], xs, p["conv_x"], p["conv_x_bias"])
    b_c, new_cb = conv_step(state["conv_b"], b_, p["conv_b"], p["conv_b_bias"])
    c_c, new_cc = conv_step(state["conv_c"], c_, p["conv_c"], p["conv_c_bias"])
    xs_c = xs_c.reshape(bsz, h, ph)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["A_log"])
    da = jnp.exp(dt * a)  # (B,H)

    s_new = state["ssm"] * da[:, :, None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xs_c, b_c
    )
    y = jnp.einsum("bhpn,bn->bhp", s_new, c_c) + p["D"][None, :, None] * xs_c
    y = y.reshape(bsz, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z[:, None, :].astype(x.dtype)), p["norm"], cfg.norm_eps)
    out = dense(y, p["out_proj"], None, cim)
    return out, {
        "ssm": s_new,
        "conv_x": new_cx.astype(state["conv_x"].dtype),
        "conv_b": new_cb.astype(state["conv_b"].dtype),
        "conv_c": new_cc.astype(state["conv_c"].dtype),
    }


def make_mamba_state(cfg: ModelConfig, batch: int, n_layers: int):
    di, h, n = _dims(cfg)
    w1 = cfg.ssm_conv_width - 1
    dt = cdtype(cfg)
    return {
        "ssm": jnp.zeros((n_layers, batch, h, cfg.ssm_headdim, n), jnp.float32),
        "conv_x": jnp.zeros((n_layers, batch, w1, di), dt),
        "conv_b": jnp.zeros((n_layers, batch, w1, n), dt),
        "conv_c": jnp.zeros((n_layers, batch, w1, n), dt),
    }
