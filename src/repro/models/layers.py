"""Shared neural-net layers (pure JAX, functional, scan-friendly).

Conventions:
  * params are nested dicts of arrays; layer-stacked params carry a leading
    ``L`` dim and are consumed via ``lax.scan`` (compact HLO for the 512-device
    dry-run).
  * every matmul goes through ``dense()`` which routes to the CiM-quantized op
    when the config enables the paper's technique.
  * attention is blocked (online softmax over KV chunks) so 32k-token prefill
    never materializes an S×S score matrix; decode (Sq == 1) uses direct
    attention so a sequence-sharded KV cache reduces via SPMD collectives
    (flash-decoding-style sequence parallelism).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig
from repro.core.cim_linear import CiMConfig, cim_matmul

_NEG = -1e30

# ---------------------------------------------------------------------------
# Activation sharding constraints
#
# Set by launch/steps.py (and the train/serve drivers) before tracing:
#   ACT_RULES = {"dp": (("data",), 16), "tp": (("model",), 16)}
# Without rules (smoke tests, single device) constraints are no-ops.
# ---------------------------------------------------------------------------

ACT_RULES: Optional[dict] = None


def set_act_rules(rules: Optional[dict]) -> None:
    global ACT_RULES
    ACT_RULES = rules


def axis_size(logical: str) -> int:
    if ACT_RULES is None or logical not in ACT_RULES:
        return 1
    return ACT_RULES[logical][1]


def constrain(x: jnp.ndarray, logical: tuple) -> jnp.ndarray:
    """with_sharding_constraint with divisibility fallback per dim."""
    if ACT_RULES is None:
        return x
    from jax.sharding import PartitionSpec as P

    spec = []
    for dim, ax in zip(x.shape, logical):
        if ax is None or ax not in ACT_RULES:
            spec.append(None)
            continue
        axes, size = ACT_RULES[ax]
        spec.append((axes if len(axes) > 1 else axes[0]) if dim % size == 0 else None)
    return jax.lax.with_sharding_constraint(x, P(*spec))

__all__ = [
    "dense",
    "rms_norm",
    "apply_rope",
    "init_attention",
    "attention",
    "decode_attention",
    "init_mlp",
    "mlp",
    "init_embedding",
    "embed",
    "chunked_xent",
]


def cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


def pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# Primitives
# ---------------------------------------------------------------------------


def dense(
    x: jnp.ndarray,
    w: jnp.ndarray,
    bias: Optional[jnp.ndarray] = None,
    cim: Optional[CiMConfig] = None,
):
    """Linear layer; routes through the CiM pipeline when enabled."""
    if cim is not None and cim.mode != "exact":
        y = cim_matmul(x, w.astype(jnp.float32), cim).astype(x.dtype)
    else:
        y = x @ w.astype(x.dtype)
    if bias is not None:
        y = y + bias.astype(y.dtype)
    return y


import functools
import os

# REPRO_LEGACY_NORM=1 restores the v1 (f32-materializing) norm/attention
# numerics — used to reproduce the paper-faithful BASELINE roofline numbers
# (EXPERIMENTS.md §Perf records both).
LEGACY_NORM = os.environ.get("REPRO_LEGACY_NORM", "0") == "1"


def _rms_norm_legacy(x, scale, eps):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))).astype(dt)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _rms_norm_fused(x, scale, eps):
    y, _ = _rms_norm_fwd(x, scale, eps)
    return y


def _rms_norm_fwd(x, scale, eps):
    """f32 statistics, x.dtype-materialized tensors (fwd AND bwd) — the
    hand-fused VJP keeps the full-hidden cotangents in the compute dtype,
    which the autodiff of an f32-upcast norm cannot (perf iteration A1,
    EXPERIMENTS.md §Perf)."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = lax.rsqrt(var + eps)
    y = x * inv.astype(x.dtype) * (1.0 + scale.astype(x.dtype))
    return y, (x, scale, inv)


def _rms_norm_bwd(eps, res, dy):
    x, scale, inv = res
    inv_x = inv.astype(x.dtype)
    xhat = x * inv_x
    g = dy * (1.0 + scale.astype(dy.dtype))
    # dx = inv * (g - xhat * mean(g * xhat));  reductions in f32, tensors in x.dtype
    mgx = jnp.mean(
        (g * xhat).astype(jnp.float32), axis=-1, keepdims=True
    ).astype(x.dtype)
    dx = inv_x * (g - xhat * mgx)
    dscale = jnp.sum(
        (dy * xhat).astype(jnp.float32), axis=tuple(range(dy.ndim - 1))
    ).astype(scale.dtype)
    return dx, dscale


_rms_norm_fused.defvjp(_rms_norm_fwd, _rms_norm_bwd)


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    if LEGACY_NORM:
        return _rms_norm_legacy(x, scale, eps)
    # Perf iteration A1b/A1c (the A1 custom-vjp variant was REFUTED — its
    # residuals defeat the scan-level remat; see EXPERIMENTS.md §Perf):
    # variance as a self-dot with f32 OUTPUT but bf16 operands — the dot
    # transpose rule keeps the backward cotangent in the compute dtype, so
    # neither pass materializes an f32 copy of the residual stream.
    var = (
        jnp.einsum("...d,...d->...", x, x, preferred_element_type=jnp.float32)
        / x.shape[-1]
    )[..., None]
    inv = lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * (1.0 + scale.astype(x.dtype))


def _rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(
    x: jnp.ndarray,  # (B, S, n, head_dim)
    positions: jnp.ndarray,  # (S,) or scalar-broadcastable int32
    theta: float,
) -> jnp.ndarray:
    hd = x.shape[-1]
    freqs = jnp.asarray(_rope_freqs(hd, theta), jnp.float32)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (S, hd/2)
    cos = jnp.cos(ang)[None, :, None, :]
    sin = jnp.sin(ang)[None, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, blocked prefill + cached decode)
# ---------------------------------------------------------------------------


def _flash_sharded(q, k, v, cfg: ModelConfig):
    """Fused flash-attention (perf iteration D): batch over dp, QUERY sequence
    over tp (each model-axis rank owns S/tp query rows against the full K/V,
    with absolute positions keeping causality exact). Score tiles never leave
    VMEM; causal KV blocks are skipped in-kernel. Forward-only — used on the
    prefill path. q arrives pre-scaled (sm_scale=1)."""
    from repro.kernels.flash_attention import flash_attention_pallas

    b, s, kv, g, hd = q.shape
    qh = q.reshape(b, s, kv * g, hd).transpose(0, 2, 1, 3)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    interpret = jax.default_backend() != "tpu"
    call = functools.partial(
        flash_attention_pallas, causal=True, sm_scale=1.0, interpret=interpret
    )

    if ACT_RULES is not None and "mesh" in ACT_RULES:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        mesh = ACT_RULES["mesh"]
        dp_axes, dp_size = ACT_RULES["dp"]
        tp_axes, tp_size = ACT_RULES["tp"]
        bspec = (dp_axes if len(dp_axes) > 1 else dp_axes[0]) if b % dp_size == 0 else None
        sspec = (tp_axes if len(tp_axes) > 1 else tp_axes[0]) if s % (tp_size * 128) == 0 else None

        def fn(qs, ks, vs):
            s_loc = qs.shape[2]
            if sspec is not None:
                off = lax.axis_index(tp_axes if len(tp_axes) > 1 else tp_axes[0]) * s_loc
            else:
                off = 0
            pos = off + jnp.arange(s_loc, dtype=jnp.int32)
            return call(qs, ks, vs, pos)

        out = shard_map(
            fn,
            mesh=mesh,
            in_specs=(
                P(bspec, None, sspec, None),
                P(bspec, None, None, None),
                P(bspec, None, None, None),
            ),
            out_specs=P(bspec, None, sspec, None),
            check_rep=False,
        )(qh, kh, vh)
    else:
        out = call(qh, kh, vh)
    return out.transpose(0, 2, 1, 3).reshape(b, s, kv, g, hd)


def init_attention(key, cfg: ModelConfig, n_layers: int):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    dt = pdtype(cfg)
    s = lambda fan_in: 1.0 / np.sqrt(fan_in)
    p = {
        "wq": jax.random.normal(ks[0], (n_layers, d, h * hd), dt) * s(d),
        "wk": jax.random.normal(ks[1], (n_layers, d, kv * hd), dt) * s(d),
        "wv": jax.random.normal(ks[2], (n_layers, d, kv * hd), dt) * s(d),
        "wo": jax.random.normal(ks[3], (n_layers, h * hd, d), dt) * s(h * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((n_layers, h * hd), dt)
        p["bk"] = jnp.zeros((n_layers, kv * hd), dt)
        p["bv"] = jnp.zeros((n_layers, kv * hd), dt)
    return p


def _blocked_sdpa(
    q: jnp.ndarray,  # (B, Sq, K, G, hd) f32-scaled
    k: jnp.ndarray,  # (B, Sk, K, hd)
    v: jnp.ndarray,  # (B, Sk, K, hd)
    q_pos: jnp.ndarray,  # (Sq,) absolute positions of queries
    k_pos: jnp.ndarray,  # (Sk,) absolute positions of keys
    chunk: int,
    window: Optional[int],
) -> jnp.ndarray:
    b, sq, kh, g, hd = q.shape
    sk = k.shape[1]
    chunk = min(chunk, sk)
    pad = (-sk) % chunk
    if pad:  # pad keys; sentinel positions never pass the causal mask
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.concatenate(
            [k_pos, jnp.full((pad,), 1 << 30, k_pos.dtype)]
        )
        sk += pad
    n_chunks = sk // chunk

    kc = k.reshape(b, n_chunks, chunk, kh, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, kh, hd).transpose(1, 0, 2, 3, 4)
    pc = k_pos.reshape(n_chunks, chunk)

    # scores/probabilities materialize in the compute dtype (bf16 on TPU);
    # the online-softmax statistics (m, l) and output accumulator stay f32
    # (perf iteration A2, EXPERIMENTS.md §Perf); REPRO_LEGACY_NORM=1 restores
    # the v1 f32 score path for baseline measurement
    sdt = jnp.float32 if LEGACY_NORM else q.dtype

    def step(carry, xs):
        m, l, acc = carry
        kci, vci, pci = xs
        s = jnp.einsum(
            "bqkgd,bckd->bqkgc", q, kci, preferred_element_type=jnp.float32
        )
        mask = pci[None, None, None, None, :] <= q_pos[None, :, None, None, None]
        if window is not None:
            mask &= pci[None, None, None, None, :] > (
                q_pos[None, :, None, None, None] - window
            )
        s = jnp.where(mask, s, _NEG)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = (jnp.exp(s - m_new[..., None]) * mask.astype(jnp.float32)).astype(sdt)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(axis=-1, dtype=jnp.float32)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bqkgc,bckd->bqkgd", p, vci, preferred_element_type=jnp.float32
        )
        return (m_new, l, acc), None

    m0 = jnp.full((b, sq, kh, g), _NEG, jnp.float32)
    l0 = jnp.zeros((b, sq, kh, g), jnp.float32)
    a0 = jnp.zeros((b, sq, kh, g, hd), jnp.float32)
    # Perf iteration A3: remat each KV-chunk step — the backward pass
    # recomputes the (B,Sq,K,G,chunk) score tile instead of saving a stacked
    # copy per chunk (flash-attention-style memory behavior in pure XLA)
    step_fn = step if LEGACY_NORM else jax.checkpoint(step)
    (m, l, acc), _ = lax.scan(step_fn, (m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out


def attention(
    p: dict,
    x: jnp.ndarray,  # (B, S, D)
    cfg: ModelConfig,
    positions: jnp.ndarray,  # (S,)
    cache: Optional[dict] = None,  # populated by prefill when serving
):
    """Full-sequence (training / prefill) GQA attention. Returns (out, cache)."""
    b, s, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = h // kv
    cim = cfg.cim

    q = constrain(dense(x, p["wq"], p.get("bq"), cim), ("dp", None, "tp")).reshape(b, s, h, hd)
    k = constrain(dense(x, p["wk"], p.get("bk"), cim), ("dp", None, "tp")).reshape(b, s, kv, hd)
    v = constrain(dense(x, p["wv"], p.get("bv"), cim), ("dp", None, "tp")).reshape(b, s, kv, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = q.reshape(b, s, kv, g, hd) / np.sqrt(hd)

    if cfg.attn_impl == "flash" and cfg.sliding_window is None:
        out = _flash_sharded(q, k, v, cfg)  # perf iteration D (fwd-only path)
    else:
        out = _blocked_sdpa(
            q, k, v, positions, positions, cfg.attn_chunk, cfg.sliding_window
        )
    out = out.astype(x.dtype).reshape(b, s, h * hd)
    out = constrain(out, ("dp", None, "tp"))
    y = constrain(dense(out, p["wo"], None, cim), ("dp", None, None))
    new_cache = None
    if cache is not None:
        sc = cache["k"].shape[1]
        if cache["k"].dtype == jnp.int8:
            # int8 KV cache: per-kv-head symmetric scales computed at prefill
            k_scale = jnp.max(jnp.abs(k.astype(jnp.float32)), axis=(0, 1, 3)) / 127.0
            v_scale = jnp.max(jnp.abs(v.astype(jnp.float32)), axis=(0, 1, 3)) / 127.0
            k_scale = jnp.maximum(k_scale, 1e-8)
            v_scale = jnp.maximum(v_scale, 1e-8)
            kq = jnp.clip(jnp.round(k.astype(jnp.float32) / k_scale[None, None, :, None]), -127, 127)
            vq = jnp.clip(jnp.round(v.astype(jnp.float32) / v_scale[None, None, :, None]), -127, 127)
            k, v = kq.astype(jnp.int8), vq.astype(jnp.int8)
            scales = {"k_scale": k_scale, "v_scale": v_scale}
        else:
            scales = {}
        if s <= sc:  # prefix fits: write at the front
            new_cache = {
                "k": lax.dynamic_update_slice(
                    cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0)
                ),
                "v": lax.dynamic_update_slice(
                    cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0)
                ),
                "pos": lax.dynamic_update_slice(
                    cache["pos"], positions.astype(jnp.int32), (0,)
                ),
                **scales,
            }
        else:  # window cache: keep last sc keys, ring-rotated (slot = pos % sc)
            shift = (s - sc) % sc
            new_cache = {
                "k": jnp.roll(k[:, -sc:].astype(cache["k"].dtype), shift, axis=1),
                "v": jnp.roll(v[:, -sc:].astype(cache["v"].dtype), shift, axis=1),
                "pos": jnp.roll(positions[-sc:].astype(jnp.int32), shift),
                **scales,
            }
    return y, new_cache


def decode_attention(
    p: dict,
    x: jnp.ndarray,  # (B, 1, D)
    cfg: ModelConfig,
    pos: jnp.ndarray,  # scalar int32 — current absolute position
    cache: dict,  # {"k": (B, Sc, KV, hd), "v": ..., "pos": (Sc,)}
):
    """Single-token cached decode. The KV cache seq dim may be sharded
    (sequence parallelism); scores reduce via SPMD-inserted collectives."""
    b, _, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = h // kv
    cim = cfg.cim

    q = dense(x, p["wq"], p.get("bq"), cim).reshape(b, 1, h, hd)
    k = dense(x, p["wk"], p.get("bk"), cim).reshape(b, 1, kv, hd)
    v = dense(x, p["wv"], p.get("bv"), cim).reshape(b, 1, kv, hd)
    q = apply_rope(q, pos[None], cfg.rope_theta)
    k = apply_rope(k, pos[None], cfg.rope_theta)

    sc = cache["k"].shape[1]
    slot = pos % sc  # ring buffer when window-capped, linear otherwise
    int8_kv = cache["k"].dtype == jnp.int8
    if int8_kv:
        ks, vs = cache["k_scale"], cache["v_scale"]  # (KV,)
        k_w = jnp.clip(
            jnp.round(k.astype(jnp.float32) / jnp.maximum(ks, 1e-8)[None, None, :, None]),
            -127, 127,
        ).astype(jnp.int8)
        v_w = jnp.clip(
            jnp.round(v.astype(jnp.float32) / jnp.maximum(vs, 1e-8)[None, None, :, None]),
            -127, 127,
        ).astype(jnp.int8)
    else:
        k_w, v_w = k.astype(cache["k"].dtype), v.astype(cache["v"].dtype)
    ck = lax.dynamic_update_slice(cache["k"], k_w, (0, slot, 0, 0))
    cv = lax.dynamic_update_slice(cache["v"], v_w, (0, slot, 0, 0))
    cpos = lax.dynamic_update_slice(cache["pos"], pos[None].astype(jnp.int32), (slot,))

    valid = (cpos <= pos) & (cpos >= 0)
    if cfg.sliding_window is not None:
        valid &= cpos > pos - cfg.sliding_window

    if int8_kv:
        # integer score dot: q dynamically quantized per kv-head; the cache is
        # read at s8 — this is the MXU analogue of the paper's in-memory
        # integer product-sum (perf iteration C2)
        qh = q.reshape(b, 1, kv, g, hd).astype(jnp.float32) / np.sqrt(hd)
        sq = jnp.max(jnp.abs(qh), axis=(0, 1, 3, 4)) / 127.0  # (KV,)
        sq = jnp.maximum(sq, 1e-8)
        q_i8 = jnp.clip(
            jnp.round(qh / sq[None, None, :, None, None]), -127, 127
        ).astype(jnp.int8)
        s_i32 = jnp.einsum(
            "bqkgd,bckd->bqkgc", q_i8, ck, preferred_element_type=jnp.int32
        )
        s = s_i32.astype(jnp.float32) * (sq * ks)[None, None, :, None, None]
        s = jnp.where(valid[None, None, None, None, :], s, _NEG)
        m = s.max(axis=-1, keepdims=True)
        pattn = jnp.exp(s - m) * valid[None, None, None, None, :].astype(jnp.float32)
        # probabilities quantized to u8-equivalent s8 so the V read stays s8
        p_i8 = jnp.clip(jnp.round(pattn * 127.0), 0, 127).astype(jnp.int8)
        o_i32 = jnp.einsum(
            "bqkgc,bckd->bqkgd", p_i8, cv, preferred_element_type=jnp.int32
        )
        out = o_i32.astype(jnp.float32) * (vs / 127.0)[None, None, :, None, None]
        out = out / jnp.maximum(pattn.sum(-1)[..., None], 1e-30)
    else:
        qf = q.reshape(b, 1, kv, g, hd).astype(jnp.float32) / np.sqrt(hd)
        s = jnp.einsum("bqkgd,bckd->bqkgc", qf, ck.astype(jnp.float32))
        s = jnp.where(valid[None, None, None, None, :], s, _NEG)
        m = s.max(axis=-1, keepdims=True)
        pattn = jnp.exp(s - m)
        pattn = pattn * valid[None, None, None, None, :].astype(jnp.float32)
        out = jnp.einsum("bqkgc,bckd->bqkgd", pattn, cv.astype(jnp.float32))
        out = out / jnp.maximum(pattn.sum(-1)[..., None], 1e-30)
    out = out.astype(x.dtype).reshape(b, 1, h * hd)
    y = constrain(dense(out, p["wo"], None, cim), ("dp", None, None))
    new_cache = {"k": ck, "v": cv, "pos": cpos}
    if int8_kv:
        new_cache["k_scale"] = ks
        new_cache["v_scale"] = vs
    return y, new_cache


def make_attn_cache(cfg: ModelConfig, batch: int, seq_len: int, n_layers: int):
    """Preallocated KV cache (seq capped to the sliding window if set).

    ``cfg.kv_quant_int8`` stores K/V as int8 with per-(layer, kv-head) scales
    — the paper's low-precision-digitization insight applied to the serving
    cache (perf iteration C2): HBM cache traffic halves vs bf16."""
    sc = seq_len if cfg.sliding_window is None else min(seq_len, cfg.sliding_window)
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    dt = jnp.int8 if cfg.kv_quant_int8 else cdtype(cfg)
    cache = {
        "k": jnp.zeros((n_layers, batch, sc, kv, hd), dt),
        "v": jnp.zeros((n_layers, batch, sc, kv, hd), dt),
        "pos": jnp.full((n_layers, sc), -1, jnp.int32),
    }
    if cfg.kv_quant_int8:
        cache["k_scale"] = jnp.full((n_layers, kv), 1e-2, jnp.float32)
        cache["v_scale"] = jnp.full((n_layers, kv), 1e-2, jnp.float32)
    return cache


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ModelConfig, n_layers: int, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    dt = pdtype(cfg)
    return {
        "w_gate": jax.random.normal(ks[0], (n_layers, d, f), dt) / np.sqrt(d),
        "w_up": jax.random.normal(ks[1], (n_layers, d, f), dt) / np.sqrt(d),
        "w_down": jax.random.normal(ks[2], (n_layers, f, d), dt) / np.sqrt(f),
    }


def mlp(p: dict, x: jnp.ndarray, cfg: ModelConfig):
    cim = cfg.cim
    sh = ("dp", None, "tp") if x.ndim == 3 else ("dp", "tp")
    gate = constrain(dense(x, p["w_gate"], None, cim), sh)
    up = constrain(dense(x, p["w_up"], None, cim), sh)
    out = dense(jax.nn.silu(gate) * up, p["w_down"], None, cim)
    return constrain(out, ("dp",) + (None,) * (x.ndim - 1))


# ---------------------------------------------------------------------------
# Embedding + chunked softmax cross-entropy
# ---------------------------------------------------------------------------


def init_embedding(key, cfg: ModelConfig):
    v, d = cfg.padded_vocab, cfg.d_model
    k1, k2 = jax.random.split(key)
    dt = pdtype(cfg)
    p = {"tok": jax.random.normal(k1, (v, d), dt) * 0.02}
    if not cfg.tie_embeddings:
        p["unembed"] = jax.random.normal(k2, (d, v), dt) / np.sqrt(d)
    return p


def embed(p: dict, tokens_or_x: jnp.ndarray, cfg: ModelConfig):
    if cfg.input_kind == "embeddings":
        return constrain(tokens_or_x.astype(cdtype(cfg)), ("dp", None, None))
    out = p["tok"][tokens_or_x].astype(cdtype(cfg))
    return constrain(out, ("dp", None, None))


def unembed_weight(p: dict, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return p["tok"].T
    return p["unembed"]


def chunked_xent(
    p: dict,
    h: jnp.ndarray,  # (B, S, D) final hidden states
    labels: jnp.ndarray,  # (B, S) int32, -1 = ignore
    cfg: ModelConfig,
) -> jnp.ndarray:
    """Mean next-token cross-entropy without materializing (B, S, V) logits.

    Scans the sequence in ``cfg.loss_chunk`` slices; each slice's logits are
    rematerialized in the backward pass (jax.checkpoint)."""
    w = unembed_weight(p, cfg)
    b, s, d = h.shape
    c = min(cfg.loss_chunk, s)
    assert s % c == 0, "pad sequence to a loss_chunk multiple"
    n = s // c
    hc = h.reshape(b, n, c, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, n, c).transpose(1, 0, 2)
    vmask = (jnp.arange(cfg.padded_vocab) < cfg.vocab).astype(jnp.float32)

    @jax.checkpoint
    def chunk_loss(hi, li):
        logits = (hi @ w.astype(hi.dtype)).astype(jnp.float32)
        logits = constrain(logits, ("dp", None, "tp"))
        logits = logits + (vmask - 1.0) * 1e9  # mask padded vocab
        lse = jax.nn.logsumexp(logits, axis=-1)
        li_safe = jnp.maximum(li, 0)
        picked = jnp.take_along_axis(logits, li_safe[..., None], axis=-1)[..., 0]
        valid = (li >= 0).astype(jnp.float32)
        return ((lse - picked) * valid).sum(), valid.sum()

    def step(carry, xs):
        tot, cnt = carry
        l, v = chunk_loss(*xs)
        return (tot + l, cnt + v), None

    (tot, cnt), _ = lax.scan(step, (jnp.zeros(()), jnp.zeros(())), (hc, lc))
    return tot / jnp.maximum(cnt, 1.0)


def logits_step(p: dict, h: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Decode-step logits (B, 1, V): direct matmul, vocab sharded over TP."""
    w = unembed_weight(p, cfg)
    logits = (h @ w.astype(h.dtype)).astype(jnp.float32)
    logits = constrain(logits, ("dp", None, "tp"))
    vmask = (jnp.arange(cfg.padded_vocab) < cfg.vocab).astype(jnp.float32)
    return logits + (vmask - 1.0) * 1e9
