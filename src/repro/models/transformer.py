"""Dense / GQA / MoE decoder stack with scan-over-layers.

All per-layer parameters are stacked on a leading L dim and consumed via
``lax.scan`` — the lowered HLO contains ONE block body regardless of depth,
which keeps the 512-device SPMD dry-run compile tractable and is the layout
pipeline-parallelism would slice at >1k-chip scale.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.moe import init_moe, moe_ffn, moe_ffn_dense

__all__ = [
    "init_transformer",
    "transformer_forward",
    "transformer_prefill",
    "transformer_decode",
]


def init_transformer(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    nl = cfg.n_layers
    p = {
        "embed": L.init_embedding(ks[0], cfg),
        "attn": L.init_attention(ks[1], cfg, nl),
        "ln1": jnp.zeros((nl, cfg.d_model), L.pdtype(cfg)),
        "ln2": jnp.zeros((nl, cfg.d_model), L.pdtype(cfg)),
        "ln_f": jnp.zeros((cfg.d_model,), L.pdtype(cfg)),
    }
    if cfg.n_experts:
        p["moe"] = init_moe(ks[2], cfg, nl)
    else:
        p["mlp"] = L.init_mlp(ks[2], cfg, nl)
    return p


def _block_train(x, lp, cfg: ModelConfig, positions):
    h, _ = L.attention(lp["attn"], L.rms_norm(x, lp["ln1"], cfg.norm_eps), cfg, positions)
    x = x + h
    hn = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
    if cfg.n_experts:
        ffn = moe_ffn_dense if cfg.moe_impl == "dense" else moe_ffn
        y, aux = ffn(lp["moe"], hn, cfg)
    else:
        y, aux = L.mlp(lp["mlp"], hn, cfg), jnp.zeros((), jnp.float32)
    return L.constrain(x + y, ("dp", None, None)), aux


def _layer_params(p: dict, cfg: ModelConfig):
    lp = {"attn": p["attn"], "ln1": p["ln1"], "ln2": p["ln2"]}
    lp["moe" if cfg.n_experts else "mlp"] = p["moe" if cfg.n_experts else "mlp"]
    return lp


def transformer_forward(p: dict, x_in: jnp.ndarray, cfg: ModelConfig):
    """Training forward: (B, S) tokens or (B, S, D) embeddings -> (h, aux)."""
    x = L.embed(p["embed"], x_in, cfg)
    b, s, _ = x.shape
    positions = jnp.arange(s, dtype=jnp.int32)

    def body(carry, lp):
        x, aux = carry
        x, a = _block_train(x, lp, cfg, positions)
        return (x, aux + a), None

    if cfg.remat != "none":
        body = jax.checkpoint(body)
    (x, aux), _ = lax.scan(body, (x, jnp.zeros((), jnp.float32)), _layer_params(p, cfg))
    h = L.rms_norm(x, p["ln_f"], cfg.norm_eps)
    return h, aux / max(cfg.n_layers, 1)


def transformer_prefill(p: dict, x_in: jnp.ndarray, cfg: ModelConfig, cache: dict):
    """Prefill: fills the per-layer KV cache, returns (h_last, cache)."""
    x = L.embed(p["embed"], x_in, cfg)
    b, s, _ = x.shape
    positions = jnp.arange(s, dtype=jnp.int32)

    def body(x, xs):
        lp, cache_l = xs
        hn = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
        h, new_cache = L.attention(lp["attn"], hn, cfg, positions, cache=cache_l)
        x = x + h
        hn = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        if cfg.n_experts:
            ffn = moe_ffn_dense if cfg.moe_impl == "dense" else moe_ffn
            y, _ = ffn(lp["moe"], hn, cfg)
        else:
            y = L.mlp(lp["mlp"], hn, cfg)
        return x + y, new_cache

    if cfg.remat != "none":
        body = jax.checkpoint(body)
    x, new_cache = lax.scan(body, x, (_layer_params(p, cfg), cache))
    h = L.rms_norm(x, p["ln_f"], cfg.norm_eps)
    return h, new_cache


def transformer_decode(p: dict, token, cfg: ModelConfig, pos, cache: dict):
    """One decode step: token (B,) or embedding (B, D) -> (logits, cache)."""
    if cfg.input_kind == "embeddings":
        x = token[:, None, :].astype(L.cdtype(cfg))
    else:
        x = L.embed(p["embed"], token[:, None], cfg)

    def body(x, xs):
        lp, cache_l = xs
        hn = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
        h, new_cache = L.decode_attention(lp["attn"], hn, cfg, pos, cache_l)
        x = x + h
        hn = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        if cfg.n_experts:
            ffn = moe_ffn_dense if cfg.moe_impl == "dense" else moe_ffn
            y, _ = ffn(lp["moe"], hn, cfg)
        else:
            y = L.mlp(lp["mlp"], hn, cfg)
        return x + y, new_cache

    x, new_cache = lax.scan(body, x, (_layer_params(p, cfg), cache))
    h = L.rms_norm(x, p["ln_f"], cfg.norm_eps)
    logits = L.logits_step(p["embed"], h, cfg)
    return logits, new_cache
