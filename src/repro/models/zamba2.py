"""Zamba2-style hybrid: Mamba2 backbone + one *shared-weight* attention block.

Every ``share_period`` Mamba2 layers, a single transformer block (whose
weights are shared across all applications, Zamba2's signature trick) is
applied. Layout: ``n_layers`` Mamba2 layers split into G = n_layers //
share_period groups (each followed by the shared block) plus a tail of
``n_layers % share_period`` Mamba2 layers.

The shared block's weights are a scan *closure constant* — faithful to the
weight sharing — while each application has its own KV cache at serve time.
Long-context cells cap the shared attention with a sliding window
(cfg.sliding_window), which is what makes the hybrid sub-quadratic-capable
(see DESIGN.md §7).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.mamba2 import (
    init_mamba,
    make_mamba_state,
    mamba_decode_step,
    mamba_forward,
)

__all__ = [
    "init_zamba",
    "zamba_forward",
    "zamba_prefill",
    "zamba_decode",
    "make_zamba_cache",
]


def _split(cfg: ModelConfig):
    g = cfg.n_layers // cfg.share_period
    tail = cfg.n_layers - g * cfg.share_period
    return g, cfg.share_period, tail


def init_zamba(key, cfg: ModelConfig):
    ks = jax.random.split(key, 5)
    squeeze = lambda t: jax.tree.map(lambda a: a[0], t)
    return {
        "embed": L.init_embedding(ks[0], cfg),
        "mamba": init_mamba(ks[1], cfg, cfg.n_layers),
        "mamba_ln": jnp.zeros((cfg.n_layers, cfg.d_model), L.pdtype(cfg)),
        "shared": {
            "attn": squeeze(L.init_attention(ks[2], cfg, 1)),
            "mlp": squeeze(L.init_mlp(ks[3], cfg, 1)),
            "ln1": jnp.zeros((cfg.d_model,), L.pdtype(cfg)),
            "ln2": jnp.zeros((cfg.d_model,), L.pdtype(cfg)),
        },
        "ln_f": jnp.zeros((cfg.d_model,), L.pdtype(cfg)),
    }


def _group_tree(tree, g, period):
    """(L, ...) stacked params -> head (G, period, ...) and tail (T, ...)."""
    head = jax.tree.map(lambda a: a[: g * period].reshape(g, period, *a.shape[1:]), tree)
    tail = jax.tree.map(lambda a: a[g * period :], tree)
    return head, tail


def _mamba_layer(x, lp, cfg, state=None, decode=False):
    hn = L.rms_norm(x, lp["ln"], cfg.norm_eps)
    if decode:
        y, new_state = mamba_decode_step(lp["p"], hn, cfg, state)
    else:
        y, new_state = mamba_forward(lp["p"], hn, cfg, state)
    return x + y, new_state


def _shared_block(x, shared, cfg, positions, cache=None, pos=None, decode=False):
    hn = L.rms_norm(x, shared["ln1"], cfg.norm_eps)
    if decode:
        h, new_cache = L.decode_attention(shared["attn"], hn, cfg, pos, cache)
    else:
        h, new_cache = L.attention(shared["attn"], hn, cfg, positions, cache=cache)
    x = x + h
    x = x + L.mlp(shared["mlp"], L.rms_norm(x, shared["ln2"], cfg.norm_eps), cfg)
    return x, new_cache


def zamba_forward(p: dict, x_in: jnp.ndarray, cfg: ModelConfig):
    """Training forward -> (h, aux=0)."""
    x = L.embed(p["embed"], x_in, cfg)
    s = x.shape[1]
    positions = jnp.arange(s, dtype=jnp.int32)
    g, period, tail = _split(cfg)
    mt = {"p": p["mamba"], "ln": p["mamba_ln"]}
    head, tailp = _group_tree(mt, g, period)

    def inner(x, lp):
        x, _ = _mamba_layer(x, lp, cfg)
        return x, None

    def group(x, gp):
        x, _ = lax.scan(inner, x, gp)
        x, _ = _shared_block(x, p["shared"], cfg, positions)
        return x, None

    inner_ = jax.checkpoint(inner) if cfg.remat != "none" else inner
    group_ = jax.checkpoint(group) if cfg.remat != "none" else group
    x, _ = lax.scan(group_, x, head)
    if tail:
        x, _ = lax.scan(inner_, x, tailp)
    return L.rms_norm(x, p["ln_f"], cfg.norm_eps), jnp.zeros((), jnp.float32)


def make_zamba_cache(cfg: ModelConfig, batch: int, seq_len: int):
    g, _, _ = _split(cfg)
    return {
        "mamba": make_mamba_state(cfg, batch, cfg.n_layers),
        "attn": L.make_attn_cache(cfg, batch, seq_len, n_layers=g),
    }


def zamba_prefill(p: dict, x_in: jnp.ndarray, cfg: ModelConfig, cache: dict):
    x = L.embed(p["embed"], x_in, cfg)
    s = x.shape[1]
    positions = jnp.arange(s, dtype=jnp.int32)
    g, period, tail = _split(cfg)
    mt = {"p": p["mamba"], "ln": p["mamba_ln"]}
    head, tailp = _group_tree(mt, g, period)
    mstate = cache["mamba"]
    mhead, mtail = _group_tree(mstate, g, period)

    def inner(x, xs):
        lp, st = xs
        x, new_st = _mamba_layer(x, lp, cfg, state=st)
        return x, new_st

    def group(x, xs):
        gp, gst, acache = xs
        x, new_st = lax.scan(inner, x, (gp, gst))
        x, new_cache = _shared_block(x, p["shared"], cfg, positions, cache=acache)
        return x, (new_st, new_cache)

    x, (new_head_st, new_attn) = lax.scan(group, x, (head, mhead, cache["attn"]))
    if tail:
        x, new_tail_st = lax.scan(inner, x, (tailp, mtail))
    else:
        new_tail_st = mtail
    merge = lambda h, t: jnp.concatenate([h.reshape(-1, *h.shape[2:]), t], axis=0)
    new_mamba = jax.tree.map(merge, new_head_st, new_tail_st)
    h = L.rms_norm(x, p["ln_f"], cfg.norm_eps)
    return h, {"mamba": new_mamba, "attn": new_attn}


def zamba_decode(p: dict, token, cfg: ModelConfig, pos, cache: dict):
    if cfg.input_kind == "embeddings":
        x = token[:, None, :].astype(L.cdtype(cfg))
    else:
        x = L.embed(p["embed"], token[:, None], cfg)
    g, period, tail = _split(cfg)
    mt = {"p": p["mamba"], "ln": p["mamba_ln"]}
    head, tailp = _group_tree(mt, g, period)
    mhead, mtail = _group_tree(cache["mamba"], g, period)

    def inner(x, xs):
        lp, st = xs
        x, new_st = _mamba_layer(x, lp, cfg, state=st, decode=True)
        return x, new_st

    def group(x, xs):
        gp, gst, acache = xs
        x, new_st = lax.scan(inner, x, (gp, gst))
        x, new_cache = _shared_block(x, p["shared"], cfg, None, cache=acache, pos=pos, decode=True)
        return x, (new_st, new_cache)

    x, (new_head_st, new_attn) = lax.scan(group, x, (head, mhead, cache["attn"]))
    if tail:
        x, new_tail_st = lax.scan(inner, x, (tailp, mtail))
    else:
        new_tail_st = mtail
    merge = lambda h, t: jnp.concatenate([h.reshape(-1, *h.shape[2:]), t], axis=0)
    new_mamba = jax.tree.map(merge, new_head_st, new_tail_st)
    h = L.rms_norm(x, p["ln_f"], cfg.norm_eps)
    logits = L.logits_step(p["embed"], h, cfg)
    return logits, {"mamba": new_mamba, "attn": new_attn}
