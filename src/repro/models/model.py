"""Unified model API: build_model(cfg) -> init / loss / prefill / decode.

Families:
  * dense / moe  -> transformer.py
  * mamba        -> pure Mamba2 stack (here)
  * hybrid       -> zamba2.py
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mamba2, transformer, zamba2

__all__ = ["Model", "build_model"]


class Model(NamedTuple):
    config: ModelConfig
    init: Callable[[jax.Array], Any]
    forward: Callable[..., Any]  # (params, inputs) -> (h, aux)
    loss_fn: Callable[..., Any]  # (params, batch) -> (loss, metrics)
    make_cache: Callable[..., Any]  # (batch, seq_len) -> cache
    prefill: Callable[..., Any]  # (params, inputs, cache) -> (logits_last, cache)
    decode_step: Callable[..., Any]  # (params, token, pos, cache) -> (logits, cache)


# ---------------------------------------------------------------------------
# Pure Mamba2 stack
# ---------------------------------------------------------------------------


def _init_mamba_lm(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    return {
        "embed": L.init_embedding(ks[0], cfg),
        "mamba": mamba2.init_mamba(ks[1], cfg, cfg.n_layers),
        "ln": jnp.zeros((cfg.n_layers, cfg.d_model), L.pdtype(cfg)),
        "ln_f": jnp.zeros((cfg.d_model,), L.pdtype(cfg)),
    }


def _mamba_lm_forward(p, x_in, cfg: ModelConfig, cache=None, decode=False, pos=None):
    if decode:
        x = (
            x_in[:, None, :].astype(L.cdtype(cfg))
            if cfg.input_kind == "embeddings"
            else L.embed(p["embed"], x_in[:, None], cfg)
        )
    else:
        x = L.embed(p["embed"], x_in, cfg)

    lp_all = {"p": p["mamba"], "ln": p["ln"]}

    def body(x, xs):
        lp, st = xs
        hn = L.rms_norm(x, lp["ln"], cfg.norm_eps)
        if decode:
            y, new_st = mamba2.mamba_decode_step(lp["p"], hn, cfg, st)
        else:
            y, new_st = mamba2.mamba_forward(lp["p"], hn, cfg, st)
        return x + y, new_st

    if cfg.remat != "none" and not decode:
        body = jax.checkpoint(body)
    if cache is None:
        dummy = None
        x, _ = lax.scan(lambda c, lp: body(c, (lp, dummy)), x, lp_all)
        new_cache = None
    else:
        x, new_cache = lax.scan(body, x, (lp_all, cache))
    h = L.rms_norm(x, p["ln_f"], cfg.norm_eps)
    return h, new_cache


# ---------------------------------------------------------------------------
# build_model
# ---------------------------------------------------------------------------


def build_model(cfg: ModelConfig) -> Model:
    fam = cfg.family

    if fam in ("dense", "moe"):
        init = lambda key: transformer.init_transformer(key, cfg)
        fwd = lambda p, x: transformer.transformer_forward(p, x, cfg)

        def make_cache(batch, seq_len):
            return L.make_attn_cache(cfg, batch, seq_len, cfg.n_layers)

        def prefill(p, x, cache):
            h, cache = transformer.transformer_prefill(p, x, cfg, cache)
            return L.logits_step(p["embed"], h[:, -1:, :], cfg), cache

        def decode_step(p, token, pos, cache):
            return transformer.transformer_decode(p, token, cfg, pos, cache)

    elif fam == "mamba":
        init = lambda key: _init_mamba_lm(key, cfg)
        fwd = lambda p, x: (_mamba_lm_forward(p, x, cfg)[0], jnp.zeros((), jnp.float32))

        def make_cache(batch, seq_len):
            return mamba2.make_mamba_state(cfg, batch, cfg.n_layers)

        def prefill(p, x, cache):
            h, cache = _mamba_lm_forward(p, x, cfg, cache=cache)
            return L.logits_step(p["embed"], h[:, -1:, :], cfg), cache

        def decode_step(p, token, pos, cache):
            h, cache = _mamba_lm_forward(p, token, cfg, cache=cache, decode=True, pos=pos)
            return L.logits_step(p["embed"], h, cfg), cache

    elif fam == "hybrid":
        init = lambda key: zamba2.init_zamba(key, cfg)
        fwd = lambda p, x: zamba2.zamba_forward(p, x, cfg)

        def make_cache(batch, seq_len):
            return zamba2.make_zamba_cache(cfg, batch, seq_len)

        def prefill(p, x, cache):
            h, cache = zamba2.zamba_prefill(p, x, cfg, cache)
            return L.logits_step(p["embed"], h[:, -1:, :], cfg), cache

        def decode_step(p, token, pos, cache):
            return zamba2.zamba_decode(p, token, cfg, pos, cache)

    else:
        raise ValueError(f"unknown family {fam!r}")

    def loss_fn(params, batch):
        h, aux = fwd(params, batch["inputs"])
        xent = L.chunked_xent(params["embed"], h, batch["labels"], cfg)
        loss = xent + cfg.router_aux_weight * aux
        return loss, {"xent": xent, "aux": aux}

    return Model(
        config=cfg,
        init=init,
        forward=fwd,
        loss_fn=loss_fn,
        make_cache=make_cache,
        prefill=prefill,
        decode_step=decode_step,
    )
