"""Model zoo: dense/GQA/MoE transformer, Mamba2 (SSD), Zamba2 hybrid."""

from repro.models.model import Model, build_model

__all__ = ["Model", "build_model"]
