"""Training workloads: LM train loop lives in launch/train.py; the paper's
MNIST-CiM evaluation lives here."""

from repro.train.mnist_mlp import evaluate, train_mlp

__all__ = ["train_mlp", "evaluate"]
