"""The paper's own evaluation workload: MNIST inference through CiM arrays.

A small MLP (256-128-64-10) is trained in float (QAT-style with the CiM
straight-through estimator optional), then evaluated with every linear routed
through the bit-plane CiM + memory-immersed-ADC pipeline at a configurable
operating point (ADC bits, search mode, clock frequency, supply voltage) —
reproducing Fig. 7(c,d) accuracy/power trends and feeding Table I/Fig. 4
benchmarks with realistic activation statistics.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.adc import ADCConfig
from repro.core.cim_linear import CiMConfig, cim_matmul
from repro.core.noise import AnalogEnv, effective_sigma
from repro.data.mnist_synth import load_mnist_synth

__all__ = ["train_mlp", "evaluate"]

_SIZES = (256, 128, 64, 10)


def _init(key):
    params = []
    for i in range(len(_SIZES) - 1):
        key, k = jax.random.split(key)
        w = jax.random.normal(k, (_SIZES[i], _SIZES[i + 1])) * np.sqrt(2.0 / _SIZES[i])
        params.append({"w": w, "b": jnp.zeros(_SIZES[i + 1])})
    return params


def _forward(params, x, cim: Optional[CiMConfig] = None, key=None):
    h = x
    for i, lyr in enumerate(params):
        if cim is not None:
            k = None
            if key is not None:
                key, k = jax.random.split(key)
            h = cim_matmul(h, lyr["w"], cim, key=k) + lyr["b"]
        else:
            h = h @ lyr["w"] + lyr["b"]
        if i < len(params) - 1:
            h = jax.nn.relu(h)
    return h


def train_mlp(epochs: int = 6, batch: int = 128, lr: float = 5e-2, seed: int = 0,
              qat_cim: Optional[CiMConfig] = None):
    """Train the MLP on synthetic MNIST; returns (params, float_test_acc)."""
    x_tr, y_tr, x_te, y_te = load_mnist_synth()
    params = _init(jax.random.PRNGKey(seed))

    @jax.jit
    def step(params, x, y):
        def loss_fn(p):
            logits = _forward(p, x, qat_cim)
            return jnp.mean(
                -jax.nn.log_softmax(logits)[jnp.arange(x.shape[0]), y]
            )

        loss, g = jax.value_and_grad(loss_fn)(params)
        params = jax.tree.map(lambda p, gi: p - lr * gi, params, g)
        return params, loss

    n = x_tr.shape[0]
    rng = np.random.default_rng(seed)
    for _ in range(epochs):
        order = rng.permutation(n)
        for i in range(0, n, batch):
            idx = order[i : i + batch]
            params, _ = step(params, jnp.asarray(x_tr[idx]), jnp.asarray(y_tr[idx]))
    acc = evaluate(params, None)
    return params, acc


def evaluate(
    params,
    cim: Optional[CiMConfig],
    env: Optional[AnalogEnv] = None,
    n_eval: int = 2048,
    seed: int = 0,
) -> float:
    """Test accuracy with linears routed through the CiM pipeline.

    ``env`` injects the frequency/voltage-dependent comparator noise of
    core.noise into the ADC model (Fig. 7c,d operating-point sweeps)."""
    _, _, x_te, y_te = load_mnist_synth()
    x_te, y_te = x_te[:n_eval], y_te[:n_eval]
    if cim is not None and env is not None:
        sigma = effective_sigma(env)
        cim = dataclasses.replace(cim, comparator_sigma=sigma)
    logits = _forward(
        params, jnp.asarray(x_te), cim, key=jax.random.PRNGKey(seed)
    )
    return float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(y_te)))
