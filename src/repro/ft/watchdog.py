"""Fault-tolerance runtime: heartbeats, straggler detection, supervised restart.

At cluster scale this is the per-host agent: it publishes heartbeats (here, a
file; in production, your scheduler's liveness channel), tracks the step-time
EMA, flags stragglers (> ``straggler_factor`` × EMA), and the supervisor
restarts the training function from the latest checkpoint on failure —
crash-consistent thanks to atomic checkpoints + seekable data (data/tokens.py
reproduces the exact batch stream at any restored step).
"""

from __future__ import annotations

import json
import time
import traceback
from pathlib import Path
from typing import Callable, Optional

__all__ = ["Watchdog", "run_with_restart"]


class Watchdog:
    def __init__(
        self,
        heartbeat_file: str | Path = "results/heartbeat.json",
        straggler_factor: float = 2.5,
        ema_alpha: float = 0.1,
    ):
        self.file = Path(heartbeat_file)
        self.factor = straggler_factor
        self.alpha = ema_alpha
        self.ema: Optional[float] = None
        self.last_t: Optional[float] = None
        self.stragglers = 0

    def step(self, step: int, metrics: dict | None = None) -> dict:
        """Call once per train step. Returns {straggler: bool, ema_s: float}."""
        now = time.time()
        out = {"straggler": False, "ema_s": None}
        if self.last_t is not None:
            dt = now - self.last_t
            if self.ema is None:
                self.ema = dt
            else:
                if dt > self.factor * self.ema:
                    out["straggler"] = True
                    self.stragglers += 1
                self.ema = (1 - self.alpha) * self.ema + self.alpha * dt
            out["ema_s"] = self.ema
        self.last_t = now
        self.file.parent.mkdir(parents=True, exist_ok=True)
        self.file.write_text(
            json.dumps(
                {
                    "step": step,
                    "time": now,
                    "ema_s": self.ema,
                    "stragglers": self.stragglers,
                    **{k: float(v) for k, v in (metrics or {}).items()},
                }
            )
        )
        return out


def run_with_restart(
    fn: Callable[[Optional[int]], int],
    max_restarts: int = 3,
    on_failure: Optional[Callable[[Exception, int], None]] = None,
) -> int:
    """Supervised execution: ``fn(resume_step)`` -> final step.

    On exception, restarts from the latest checkpoint (fn re-reads it).
    Simulates the cluster supervisor's reschedule-on-node-failure loop.
    """
    attempt = 0
    resume: Optional[int] = None
    while True:
        try:
            return fn(resume)
        except KeyboardInterrupt:
            raise
        except Exception as e:  # noqa: BLE001 — supervisor catches everything
            attempt += 1
            if on_failure:
                on_failure(e, attempt)
            if attempt > max_restarts:
                raise
            print(f"[ft] failure #{attempt}: {e!r}; restarting from latest ckpt")
            traceback.print_exc()
            resume = None  # fn re-discovers latest checkpoint
