"""Fault tolerance: watchdog, straggler detection, supervised restart."""

from repro.ft.watchdog import Watchdog, run_with_restart

__all__ = ["Watchdog", "run_with_restart"]
