"""Behavioral model of an 8T bit-plane compute-in-SRAM array (paper Fig. 2).

The array stores 1-bit weight planes down ``rows`` word lines. One 1-bit input
plane is applied per cycle on the input lines (IL); a column line (CL)
discharges only where stored bit AND input bit are both '1'; merging CLs on
the sum lines (SL) charge-averages the column results into the analog
multiply-average voltage ``V_MAV = VDD * (1/R) * sum_r x_r * w_rc``.

Signed multibit operands use two's-complement bit planes recombined digitally
with signed powers of two (the MSB plane carries weight ``-2^(n-1)``).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "bit_planes",
    "plane_weights",
    "from_bit_planes",
    "CiMArrayModel",
]


def bit_planes(x_int: jnp.ndarray, bits: int, signed: bool) -> jnp.ndarray:
    """Decompose integers into bit planes, LSB first: output (bits, *x.shape).

    Signed inputs are interpreted in two's complement over ``bits`` bits; the
    recombination weights come from :func:`plane_weights`.
    """
    x = x_int.astype(jnp.int32)
    if signed:
        x = jnp.where(x < 0, x + (1 << bits), x)  # two's complement pattern
    shifts = jnp.arange(bits, dtype=jnp.int32).reshape((bits,) + (1,) * x.ndim)
    return ((x[None] >> shifts) & 1).astype(jnp.int32)


def plane_weights(bits: int, signed: bool) -> np.ndarray:
    """Digital recombination weight of each plane (LSB first)."""
    w = 2.0 ** np.arange(bits)
    if signed:
        w[-1] = -w[-1]
    return w


def from_bit_planes(planes: jnp.ndarray, bits: int, signed: bool) -> jnp.ndarray:
    """Inverse of :func:`bit_planes` (for tests)."""
    w = jnp.asarray(plane_weights(bits, signed)).reshape(
        (bits,) + (1,) * (planes.ndim - 1)
    )
    return (planes * w).sum(axis=0).astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class CiMArrayModel:
    """One physical CiM array: geometry + analog non-idealities.

    ``mav_sigma`` is the *residual* relative error of the analog MAV after the
    common-mode cancellation the paper gets from using an identical neighbor
    array for reference generation (§II-A) — small by construction.
    """

    rows: int = 16
    cols: int = 32
    vdd: float = 1.0
    mav_sigma: float = 0.0

    def compute_mav(
        self,
        x_bits: jnp.ndarray,  # (..., rows) int {0,1} — one input plane
        w_bits: jnp.ndarray,  # (rows, cols) int {0,1} — one stored weight plane
        key: Optional[jax.Array] = None,
    ) -> jnp.ndarray:
        """Analog MAV voltages (..., cols) in [0, VDD]."""
        if x_bits.shape[-1] != self.rows or w_bits.shape != (self.rows, self.cols):
            raise ValueError(
                f"shape mismatch: x{x_bits.shape} w{w_bits.shape} "
                f"array {self.rows}x{self.cols}"
            )
        mav = x_bits.astype(jnp.float32) @ w_bits.astype(jnp.float32) / self.rows
        v = mav * self.vdd
        if self.mav_sigma > 0.0:
            if key is None:
                raise ValueError("mav noise requires a PRNG key")
            v = v + self.mav_sigma * self.vdd * jax.random.normal(key, v.shape)
        return v
