"""Asymmetric (distribution-optimal) binary search trees for SAR digitization.

The paper (Fig. 4) replaces the symmetric SAR binary search with an asymmetric
search tree matched to the skewed MAV distribution, reducing the mean number of
comparisons for 5-bit conversion from 5 to ~3.7.

A search tree here is an *alphabetic* binary tree: leaves are the 2^B output
codes in order; each internal node compares V_MAV against the threshold
between two adjacent codes (go left if below). Expected comparisons =
sum_k p[k] * depth(leaf k). We build:

  * ``symmetric_tree(bits)``        — the standard balanced SAR tree.
  * ``optimal_tree(pmf)``           — exact optimal alphabetic tree
                                      (interval DP with Knuth's speedup, O(n^2)).
  * ``weight_balanced_tree(pmf)``   — greedy median-of-mass splitting, O(n log n);
                                      near-optimal, used as a cheap online fallback.

Trees are lowered to flat integer tables (``TreeTables``) so ADC conversion can
traverse them inside ``jax.jit`` with ``lax`` control flow.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = [
    "TreeTables",
    "symmetric_tree",
    "optimal_tree",
    "weight_balanced_tree",
    "expected_comparisons",
    "validate_tree",
]


@dataclasses.dataclass(frozen=True)
class TreeTables:
    """Flat representation of an alphabetic binary search tree.

    Node 0 is the root. For internal node ``i``:
      * ``threshold[i]`` — code-boundary index t; the comparison is
        ``v >= t * LSB`` (i.e. boundary between code t-1 and code t).
      * ``left[i]`` / ``right[i]`` — child indices. Negative entries encode
        leaves: child ``-(code+1)`` means "emit code".
    ``depth[k]`` — number of comparisons to reach leaf ``k``.
    """

    threshold: np.ndarray  # (n_internal,) int32
    left: np.ndarray  # (n_internal,) int32
    right: np.ndarray  # (n_internal,) int32
    depth: np.ndarray  # (n_codes,) int32
    n_codes: int

    @property
    def max_depth(self) -> int:
        return int(self.depth.max())

    def expected_depth(self, pmf: np.ndarray) -> float:
        pmf = np.asarray(pmf, dtype=np.float64)
        return float((pmf * self.depth).sum() / pmf.sum())


class _Node:
    __slots__ = ("lo", "hi", "split", "left", "right")

    def __init__(self, lo, hi, split=None, left=None, right=None):
        self.lo, self.hi = lo, hi
        self.split, self.left, self.right = split, left, right


def _flatten(root: _Node, n_codes: int) -> TreeTables:
    threshold, left, right = [], [], []
    depth = np.zeros(n_codes, dtype=np.int32)

    def alloc(node: _Node) -> int:
        idx = len(threshold)
        threshold.append(0)
        left.append(0)
        right.append(0)
        return idx

    def fill(node: _Node, idx: int, d: int) -> None:
        threshold[idx] = node.split
        for side, child in (("l", node.left), ("r", node.right)):
            if child.lo == child.hi:  # leaf
                enc = -(child.lo + 1)
                depth[child.lo] = d + 1
                if side == "l":
                    left[idx] = enc
                else:
                    right[idx] = enc
            else:
                cidx = alloc(child)
                if side == "l":
                    left[idx] = cidx
                else:
                    right[idx] = cidx
                fill(child, cidx, d + 1)

    if root.lo == root.hi:  # degenerate single-code tree
        return TreeTables(
            threshold=np.zeros(0, np.int32),
            left=np.zeros(0, np.int32),
            right=np.zeros(0, np.int32),
            depth=np.zeros(n_codes, np.int32),
            n_codes=n_codes,
        )
    ridx = alloc(root)
    fill(root, ridx, 0)
    return TreeTables(
        threshold=np.asarray(threshold, np.int32),
        left=np.asarray(left, np.int32),
        right=np.asarray(right, np.int32),
        depth=depth,
        n_codes=n_codes,
    )


def symmetric_tree(bits: int) -> TreeTables:
    """Standard balanced SAR search over 2**bits codes (depth == bits)."""
    n = 1 << bits

    def build(lo, hi):
        if lo == hi:
            return _Node(lo, hi)
        mid = (lo + hi + 1) // 2  # boundary index between mid-1 and mid
        node = _Node(lo, hi, split=mid)
        node.left = build(lo, mid - 1)
        node.right = build(mid, hi)
        return node

    return _flatten(build(0, n - 1), n)


def optimal_tree(pmf: np.ndarray) -> TreeTables:
    """Exact optimal alphabetic search tree for code distribution ``pmf``.

    Interval DP: ``cost[i][j]`` = minimal expected comparisons (unnormalized)
    for codes i..j; every split adds one comparison for the whole interval mass.
    Knuth's monotonicity bound on the optimal split keeps it O(n^2).
    """
    p = np.asarray(pmf, dtype=np.float64)
    n = p.size
    if n < 1:
        raise ValueError("pmf must be non-empty")
    if n == 1:
        return _flatten(_Node(0, 0), 1)
    if np.any(p < 0):
        raise ValueError("pmf entries must be >= 0")
    # Regularize zero-mass codes slightly so the tree stays total (every code
    # reachable), as the hardware must emit a code for every voltage.
    p = p + 1e-12
    csum = np.concatenate([[0.0], np.cumsum(p)])

    cost = np.zeros((n, n), dtype=np.float64)
    best = np.zeros((n, n), dtype=np.int32)
    for i in range(n):
        best[i, i] = i
    for length in range(2, n + 1):
        for i in range(0, n - length + 1):
            j = i + length - 1
            mass = csum[j + 1] - csum[i]
            lo = best[i, j - 1] if length > 2 else i + 1
            hi = best[i + 1, j] if length > 2 else j
            lo = max(lo, i + 1)
            hi = min(max(hi, lo), j)
            bval, bk = np.inf, lo
            for k in range(lo, hi + 1):
                c = cost[i, k - 1] + cost[k, j]
                if c < bval:
                    bval, bk = c, k
            cost[i, j] = bval + mass
            best[i, j] = bk

    def build(lo, hi):
        if lo == hi:
            return _Node(lo, hi)
        k = int(best[lo, hi])
        node = _Node(lo, hi, split=k)
        node.left = build(lo, k - 1)
        node.right = build(k, hi)
        return node

    return _flatten(build(0, n - 1), n)


def weight_balanced_tree(pmf: np.ndarray) -> TreeTables:
    """Greedy tree: split each interval at the boundary nearest half its mass."""
    p = np.asarray(pmf, dtype=np.float64) + 1e-12
    n = p.size
    csum = np.concatenate([[0.0], np.cumsum(p)])

    def build(lo, hi):
        if lo == hi:
            return _Node(lo, hi)
        target = 0.5 * (csum[lo] + csum[hi + 1])
        k = int(np.searchsorted(csum, target, side="left"))
        k = min(max(k, lo + 1), hi)
        node = _Node(lo, hi, split=k)
        node.left = build(lo, k - 1)
        node.right = build(k, hi)
        return node

    return _flatten(build(0, n - 1), n)


def expected_comparisons(tree: TreeTables, pmf: np.ndarray) -> float:
    return tree.expected_depth(pmf)


def validate_tree(tree: TreeTables) -> None:
    """Structural validation: every code reachable exactly once, thresholds
    consistent with the alphabetic ordering (in-order traversal of thresholds
    is strictly increasing and equals 1..n-1)."""
    n = tree.n_codes
    if n == 1:
        return
    seen_codes: list[int] = []
    seen_thresholds: list[int] = []

    def walk(ref: int) -> None:
        if ref < 0:
            seen_codes.append(-ref - 1)
            return
        walk(int(tree.left[ref]))
        seen_thresholds.append(int(tree.threshold[ref]))
        walk(int(tree.right[ref]))

    walk(0)
    if seen_codes != list(range(n)):
        raise AssertionError(f"codes not in order: {seen_codes}")
    if seen_thresholds != list(range(1, n)):
        raise AssertionError(f"thresholds not alphabetic: {seen_thresholds}")
