"""Minimal distribution helpers (scipy is not available offline)."""

from __future__ import annotations

import numpy as np

__all__ = ["binom_pmf"]


def binom_pmf(n: int, p: float) -> np.ndarray:
    """Binomial(n, p) pmf over k = 0..n, computed stably in log space."""
    if not 0.0 <= p <= 1.0:
        raise ValueError("p must be in [0, 1]")
    k = np.arange(n + 1)
    if p == 0.0:
        out = np.zeros(n + 1)
        out[0] = 1.0
        return out
    if p == 1.0:
        out = np.zeros(n + 1)
        out[-1] = 1.0
        return out
    from math import lgamma

    log_comb = np.array(
        [lgamma(n + 1) - lgamma(i + 1) - lgamma(n - i + 1) for i in k]
    )
    logp = log_comb + k * np.log(p) + (n - k) * np.log1p(-p)
    pmf = np.exp(logp)
    return pmf / pmf.sum()
