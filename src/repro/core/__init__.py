"""Core library: memory-immersed collaborative digitization for CiM inference."""

from repro.core.adc import ADCConfig, ADCResult, convert, dequantize, quantize_ideal
from repro.core.cim_linear import CiMConfig, cim_matmul
from repro.core.search_tree import optimal_tree, symmetric_tree, weight_balanced_tree

__all__ = [
    "ADCConfig",
    "ADCResult",
    "convert",
    "dequantize",
    "quantize_ideal",
    "CiMConfig",
    "cim_matmul",
    "optimal_tree",
    "symmetric_tree",
    "weight_balanced_tree",
]
