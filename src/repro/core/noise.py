"""Frequency / supply-voltage scaling models (paper Fig. 7c,d).

The paper sweeps clock frequency and VDD on the 65 nm chip and reports MNIST
accuracy and power. We model the two dominant mechanisms:

  * **Frequency** — at short clock periods the comparator/DAC settling becomes
    incomplete; the residual settling error acts like extra input-referred
    noise growing as ``exp(-T_clk / tau)``.
  * **Voltage** — comparator input-referred noise is roughly constant in
    absolute volts, so the *relative* noise (vs the full-scale VDD) grows as
    VDD drops; conversion energy scales as C·V².

Constants are calibrated so that the chip's reported operating point
(10 MHz, 1.0 V, 74.23 pJ / 5-bit conversion) is reproduced and accuracy
degrades in the >40 MHz / <0.8 V regime, matching the paper's trend.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["AnalogEnv", "effective_sigma", "conversion_energy_pj", "power_uw"]

# Calibration anchors (65 nm test chip, Table I / Fig. 7)
_NOMINAL_VDD = 1.0  # V
_NOMINAL_FREQ = 10e6  # Hz
_BASE_SIGMA = 2e-3  # V rms comparator noise at nominal point
_SETTLE_TAU = 2.2e-9  # s — settling time constant of DAC+comparator
_SETTLE_T0 = 8.0e-9  # s — fixed non-settling overhead per cycle
_E_CYCLE_PJ = 74.23 / 5.0  # pJ per comparison cycle at nominal (Table I)


@dataclasses.dataclass(frozen=True)
class AnalogEnv:
    """Operating point of the analog periphery."""

    freq_hz: float = _NOMINAL_FREQ
    vdd: float = _NOMINAL_VDD


def effective_sigma(env: AnalogEnv) -> float:
    """Input-referred comparator noise [V rms] at the operating point."""
    # Voltage: absolute noise mildly increases as VDD drops (gm degradation).
    v_term = _BASE_SIGMA * (_NOMINAL_VDD / env.vdd) ** 1.5
    # Frequency: incomplete settling leaves a deterministic-ish residue that we
    # treat as noise; full-scale referred.
    t_clk = 1.0 / env.freq_hz
    settle = np.exp(-max(t_clk - _SETTLE_T0, 0.0) / _SETTLE_TAU)
    f_term = env.vdd * 0.5 * settle
    return float(np.sqrt(v_term**2 + f_term**2))


def conversion_energy_pj(env: AnalogEnv, comparisons: float) -> float:
    """Energy of one conversion [pJ]: cycles × CV² -scaled cycle energy."""
    return float(comparisons * _E_CYCLE_PJ * (env.vdd / _NOMINAL_VDD) ** 2)


def power_uw(env: AnalogEnv, comparisons_per_conversion: float) -> float:
    """ADC power [µW] at full conversion rate (one conversion per
    ``comparisons`` cycles)."""
    conv_rate = env.freq_hz / max(comparisons_per_conversion, 1e-9)
    e_pj = conversion_energy_pj(env, comparisons_per_conversion)
    return float(e_pj * 1e-12 * conv_rate * 1e6)
