"""CiM-quantized matmul / linear layer — the paper's technique as a framework op.

A matmul ``y = x @ w`` is mapped onto bit-plane compute-in-SRAM arrays:
the reduction dimension K is split into tiles of ``rows`` (one CiM array's
word lines each); activations/weights are quantized to ``a_bits``/``w_bits``
and bit-sliced; every (input-plane × weight-plane) product-sum is computed in
the charge domain as an analog MAV and digitized by the *memory-immersed ADC*
of a proximal array (core.adc); the B-bit codes are recombined digitally with
signed powers of two and the per-tile partial sums are accumulated.

Three fidelity modes:

  * ``exact``      — plain matmul (no CiM). Baseline / training default.
  * ``bitplane``   — faithful per-plane simulation (A·W plane pairs, per-plane
                     ADC with the full noise model). Exactly equals the integer
                     matmul when the ADC resolves the row count
                     (2^adc_bits >= 2·rows, as on the 16-row, 5-bit chip).
  * ``fake_quant`` — fast vectorized surrogate: integer per-tile partial sums
                     passed through an RMS-equivalent composite quantizer
                     (single matmul; used for large-model inference and QAT).

``ste=True`` wraps the quantized output in a straight-through estimator so the
op is trainable (QAT).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import search_tree as st
from repro.core.adc import (
    ADCConfig,
    ADCResult,
    convert,
    dequantize,
    make_reference_ladder,
)
from repro.core.cim_array import bit_planes, plane_weights
from repro.core.mav_stats import analytic_code_pmf

__all__ = ["CiMConfig", "CimStats", "cim_matmul", "cim_linear", "quantize_symmetric"]


@dataclasses.dataclass(frozen=True)
class CiMConfig:
    """Static configuration of the CiM mapping for one linear layer."""

    mode: str = "fake_quant"  # exact | fake_quant | bitplane
    a_bits: int = 8
    w_bits: int = 8
    adc_bits: int = 5
    rows: int = 16  # word lines per CiM array (reduction-tile size)
    a_signed: bool = True  # post-ReLU activations may use unsigned planes
    w_signed: bool = True
    search: str = "sar"  # sar | sar_asym — affects cost accounting (+codes under noise)
    comparator_sigma: float = 0.0
    ref_mismatch_sigma: float = 0.0
    ste: bool = True  # straight-through estimator (QAT)
    exact_counts: bool = False  # round reconstructed counts to integers

    def __post_init__(self):
        if self.mode not in ("exact", "fake_quant", "bitplane", "int8_dot"):
            raise ValueError(f"unknown CiM mode {self.mode!r}")

    def adc_config(self) -> ADCConfig:
        return ADCConfig(
            bits=self.adc_bits,
            n_ref_columns=max(32, 1 << self.adc_bits),
            comparator_sigma=self.comparator_sigma,
            ref_mismatch_sigma=self.ref_mismatch_sigma,
            mode="sar_asym" if self.search == "sar_asym" else "sar",
        )

    def search_tree(self) -> st.TreeTables:
        if self.search == "sar_asym":
            pmf = analytic_code_pmf(self.rows, self.adc_bits)
            return st.optimal_tree(pmf)
        return st.symmetric_tree(self.adc_bits)


class CimStats(NamedTuple):
    conversions: jnp.ndarray  # total ADC conversions performed
    comparisons: jnp.ndarray  # total comparator firings (energy proxy)


def quantize_symmetric(
    x: jnp.ndarray, bits: int, signed: bool, per_axis: Optional[int] = None
):
    """Uniform symmetric quantization. Returns (x_int float32, scale)."""
    if per_axis is not None:
        red = tuple(i for i in range(x.ndim) if i != per_axis % x.ndim)
        absmax = jnp.max(jnp.abs(x) if signed else jnp.maximum(x, 0), axis=red, keepdims=True)
    else:
        absmax = jnp.max(jnp.abs(x) if signed else jnp.maximum(x, 0))
    qmax = (1 << (bits - 1)) - 1 if signed else (1 << bits) - 1
    scale = jnp.where(absmax > 0, absmax / qmax, 1.0)
    lo = -qmax - 1 if signed else 0
    x_int = jnp.clip(jnp.round(x / scale), lo, qmax)
    return x_int, scale


# ---------------------------------------------------------------------------
# Faithful bit-plane path
# ---------------------------------------------------------------------------


def _pad_reduction(x_int, w_int, rows):
    k = x_int.shape[-1]
    pad = (-k) % rows
    if pad:
        x_int = jnp.pad(x_int, ((0, 0), (0, pad)))
        w_int = jnp.pad(w_int, ((0, pad), (0, 0)))
    return x_int, w_int, (k + pad) // rows


def _bitplane_matmul(x_int, w_int, cfg: CiMConfig, key, row_offset=0):
    """x_int (M,K) @ w_int (K,N) through per-plane CiM arrays + in-memory ADC.

    ``row_offset`` is the global index of ``x_int``'s first row. With a key,
    comparator noise is drawn PER ROW from ``fold_in(cmp_key, row_offset+i)``
    (the mismatch ladder stays shared — the reference DAC is one physical
    array), so a row's draws depend only on its global row index: never on
    the total batch shape, and never on which data shard executes it. That
    row-shape invariance is what lets a zero-padded bucketed batch
    (``fabric.autotune``) stay bit-exact to the unpadded run row by row.

    Returns (y_int float32 (M,N), CimStats).
    """
    m, _ = x_int.shape
    n = w_int.shape[1]
    r = cfg.rows
    x_int, w_int, t = _pad_reduction(x_int, w_int, r)

    xb = bit_planes(x_int, cfg.a_bits, cfg.a_signed)  # (A, M, K)
    wb = bit_planes(w_int, cfg.w_bits, cfg.w_signed)  # (W, K, N)
    xb = xb.reshape(cfg.a_bits, m, t, r).astype(jnp.float32)
    wb = wb.reshape(cfg.w_bits, t, r, n).astype(jnp.float32)

    # analog MAV of every (plane_a, plane_w, tile): (A, W, M, T, N) in [0,1]
    mav = jnp.einsum("amtr,btrn->abmtn", xb, wb) / r
    # half-LSB bias (standard comparator/DAC offset) so the discrete MAV
    # levels k/R sit mid-bin instead of exactly on code boundaries — without
    # it, arbitrarily small comparator noise flips boundary codes at p=0.5
    mav = mav + 0.5 / (1 << cfg.adc_bits)

    adc_cfg = cfg.adc_config()
    tree = cfg.search_tree()
    if key is None:
        res: ADCResult = convert(mav, adc_cfg, key=None, tree=tree)
    else:
        mismatch_key, cmp_key = jax.random.split(key)
        ladder = make_reference_ladder(adc_cfg, mismatch_key)
        row_ids = jnp.asarray(row_offset, jnp.int32) + jnp.arange(m, dtype=jnp.int32)
        row_keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(cmp_key, row_ids)
        res = jax.vmap(
            lambda v_row, k_row: convert(
                v_row, adc_cfg, key=k_row, tree=tree, ladder=ladder
            ),
            in_axes=(2, 0),
            out_axes=2,
        )(mav, row_keys)
    # floor reconstruction: digital output is the raw code scaled by one LSB,
    # zero-bias on empty tiles and exact whenever 2^adc_bits >= 2*rows
    v_hat = res.codes.astype(jnp.float32) / (1 << cfg.adc_bits) * adc_cfg.vdd
    counts = v_hat * r  # reconstructed per-array discharge counts
    if cfg.exact_counts:
        counts = jnp.round(counts)

    wa = jnp.asarray(plane_weights(cfg.a_bits, cfg.a_signed), jnp.float32)
    ww = jnp.asarray(plane_weights(cfg.w_bits, cfg.w_signed), jnp.float32)
    y_int = jnp.einsum("abmtn,a,b->mn", counts, wa, ww)
    stats = CimStats(
        conversions=jnp.asarray(mav.size, jnp.int32),
        comparisons=res.comparisons.astype(jnp.float32).sum().astype(jnp.int32),
    )
    return y_int, stats


# ---------------------------------------------------------------------------
# Fast fake-quant surrogate
# ---------------------------------------------------------------------------


def _fake_quant_matmul(x_int, w_int, cfg: CiMConfig):
    """Integer per-tile partial sums + RMS-equivalent composite quantizer.

    Each plane-pair's count is independently quantized with step R/2^B; the
    equivalent single quantizer on the composite tile partial sum uses the
    RMS combination of the plane recombination weights.
    """
    m, _ = x_int.shape
    n = w_int.shape[1]
    r = cfg.rows
    x_int, w_int, t = _pad_reduction(x_int, w_int, r)
    xt = x_int.reshape(m, t, r)
    wt = w_int.reshape(t, r, n)
    partial = jnp.einsum("mtr,trn->mtn", xt, wt)  # (M, T, N) integer-valued

    wa = plane_weights(cfg.a_bits, cfg.a_signed)
    ww = plane_weights(cfg.w_bits, cfg.w_signed)
    rms = float(np.sqrt((wa**2).sum()) * np.sqrt((ww**2).sum()))
    step = (r / (1 << cfg.adc_bits)) * rms
    q = jnp.round(partial / step) * step
    return q.sum(axis=1), step


# ---------------------------------------------------------------------------
# Public op
# ---------------------------------------------------------------------------


def cim_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    cfg: CiMConfig,
    key: Optional[jax.Array] = None,
    return_stats: bool = False,
):
    """``y = x @ w`` through the CiM + memory-immersed-ADC pipeline.

    ``x``: (..., K); ``w``: (K, N). Leading dims of x are flattened.
    """
    if cfg.mode == "exact":
        y = x @ w
        if return_stats:
            z = jnp.zeros((), jnp.int32)
            return y, CimStats(z, z)
        return y

    if cfg.mode == "int8_dot":
        # TPU-native adaptation of the paper's low-precision digitization:
        # integer product-sums on the MXU (s8 x s8 -> s32), per-channel
        # weight scales — the serving path's HBM reads are int8 end-to-end
        # (perf iteration C1, EXPERIMENTS.md §Perf).
        batch_shape = x.shape[:-1]
        xm = x.reshape(-1, x.shape[-1])
        x_int, sx = quantize_symmetric(xm, 8, True)
        w_int, sw = quantize_symmetric(w, 8, True, per_axis=-1)
        y_i32 = jax.lax.dot_general(
            x_int.astype(jnp.int8),
            w_int.astype(jnp.int8),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        y_q = y_i32.astype(jnp.float32) * sx * sw
        if cfg.ste:
            y_lin = xm @ w
            y_q = y_lin + jax.lax.stop_gradient(y_q.astype(y_lin.dtype) - y_lin)
        y = y_q.reshape(*batch_shape, w.shape[1]).astype(x.dtype)
        if return_stats:
            z = jnp.zeros((), jnp.int32)
            return y, CimStats(z, z)
        return y

    batch_shape = x.shape[:-1]
    k = x.shape[-1]
    xm = x.reshape(-1, k)

    x_int, sx = quantize_symmetric(xm, cfg.a_bits, cfg.a_signed)
    w_int, sw = quantize_symmetric(w, cfg.w_bits, cfg.w_signed, per_axis=-1)

    stats = None
    if cfg.mode == "bitplane":
        y_int, stats = _bitplane_matmul(x_int, w_int, cfg, key)
    else:
        y_int, _ = _fake_quant_matmul(x_int, w_int, cfg)
    y_q = y_int * sx * sw  # sw broadcasts (1, N)

    if cfg.ste:
        y_lin = xm @ w
        y_q = y_lin + jax.lax.stop_gradient(y_q - y_lin)

    y = y_q.reshape(*batch_shape, w.shape[1])
    if return_stats:
        if stats is None:
            z = jnp.zeros((), jnp.int32)
            stats = CimStats(z, z)
        return y, stats
    return y


def cim_linear(
    x: jnp.ndarray,
    w: jnp.ndarray,
    bias: Optional[jnp.ndarray] = None,
    cfg: Optional[CiMConfig] = None,
    key: Optional[jax.Array] = None,
):
    """Linear layer front-end used by the model zoo."""
    if cfg is None or cfg.mode == "exact":
        y = x @ w
    else:
        y = cim_matmul(x, w, cfg, key=key)
    if bias is not None:
        y = y + bias
    return y


def digitization_stats(cfg: CiMConfig, m: int, k: int, n: int) -> dict:
    """Analytic per-matmul digitization cost (conversions, expected
    comparisons) for the configured search under the Binomial MAV model."""
    t = -(-k // cfg.rows)
    conversions = cfg.a_bits * cfg.w_bits * m * t * n
    pmf = analytic_code_pmf(cfg.rows, cfg.adc_bits)
    tree = cfg.search_tree()
    e_cmp = tree.expected_depth(pmf)
    return {
        "conversions": conversions,
        "expected_comparisons_per_conversion": e_cmp,
        "total_comparisons": conversions * e_cmp,
    }
