"""Memory-immersed ADC transfer functions (JAX).

Behavioral models of the paper's SRAM-immersed digitization modes:

  * ``sar``      — successive approximation via the neighbor array's capacitive
                   DAC (symmetric balanced search, ``bits`` comparisons).
  * ``sar_asym`` — SAR driven by an asymmetric search tree matched to the MAV
                   distribution (paper Fig. 4; ~3.7 comparisons @ 5 bits).
  * ``flash``    — one-to-many coupling: 2^bits - 1 references generated in
                   parallel by proximal arrays (1 cycle).
  * ``hybrid``   — ``flash_bits`` MSBs in one Flash cycle, remaining bits in
                   SAR (optionally asymmetric per-segment trees), paper Fig. 3.
  * ``ideal``    — noiseless quantizer (oracle).

Non-idealities modeled: input-referred comparator noise (rms volts, fresh per
comparison), unit-capacitor mismatch of the memory-immersed capacitive DAC
(relative sigma; produces DNL/INL as in paper Fig. 6), and frequency/voltage
dependent noise injected via ``core.noise``.

All converters return ``ADCResult(codes, comparisons, cycles)`` where
``comparisons`` counts comparator firings (energy) and ``cycles`` counts
sequential comparison cycles (latency).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import search_tree as st

__all__ = [
    "ADCConfig",
    "ADCResult",
    "make_reference_ladder",
    "convert",
    "quantize_ideal",
    "dequantize",
    "measure_transfer",
    "dnl_inl",
    "stack_trees",
]


@dataclasses.dataclass(frozen=True)
class ADCConfig:
    """Static configuration of one memory-immersed ADC instance."""

    bits: int = 5
    vdd: float = 1.0
    n_ref_columns: int = 32  # unit caps (columns) in the reference array
    comparator_sigma: float = 0.0  # input-referred rms noise [V]
    ref_mismatch_sigma: float = 0.0  # relative unit-cap mismatch sigma
    mode: str = "sar"  # sar | sar_asym | flash | hybrid | ideal
    flash_bits: int = 2  # MSBs resolved in the flash phase of hybrid mode

    def __post_init__(self):
        if self.mode not in ("sar", "sar_asym", "flash", "hybrid", "ideal"):
            raise ValueError(f"unknown ADC mode {self.mode!r}")
        if self.n_ref_columns < (1 << self.bits):
            raise ValueError(
                "reference array must have >= 2^bits columns to generate all "
                f"thresholds (got {self.n_ref_columns} < {1 << self.bits})"
            )
        if self.mode == "hybrid" and not (0 < self.flash_bits < self.bits):
            raise ValueError("hybrid mode needs 0 < flash_bits < bits")

    @property
    def n_codes(self) -> int:
        return 1 << self.bits

    @property
    def lsb(self) -> float:
        return self.vdd / self.n_codes


class ADCResult(NamedTuple):
    codes: jnp.ndarray  # int32, same shape as input voltage
    comparisons: jnp.ndarray  # int32, comparator firings per conversion
    cycles: jnp.ndarray  # int32, sequential cycles per conversion


# ---------------------------------------------------------------------------
# Reference generation (memory-immersed capacitive DAC)
# ---------------------------------------------------------------------------


def make_reference_ladder(
    cfg: ADCConfig, key: Optional[jax.Array] = None
) -> jnp.ndarray:
    """Boundary voltages (2^bits + 1,) produced by the neighbor CiM array.

    Boundary ``t`` precharges ``m = round(t * n_cols / 2^bits)`` of the
    neighbor array's column lines to VDD (rest to GND) and charge-shares:
    ``V = VDD * sum(C_precharged) / sum(C_all)``. Unit-cap mismatch makes the
    ladder non-uniform — the source of DNL/INL in paper Fig. 6.
    """
    n = cfg.n_ref_columns
    if key is not None and cfg.ref_mismatch_sigma > 0.0:
        caps = 1.0 + cfg.ref_mismatch_sigma * jax.random.normal(key, (n,))
        caps = jnp.maximum(caps, 1e-3)
    else:
        caps = jnp.ones((n,))
    csum = jnp.concatenate([jnp.zeros((1,)), jnp.cumsum(caps)])
    m = np.round(np.arange(cfg.n_codes + 1) * n / cfg.n_codes).astype(np.int32)
    return cfg.vdd * csum[m] / csum[n]


# ---------------------------------------------------------------------------
# Ideal quantizer (oracle) and dequantization
# ---------------------------------------------------------------------------


def quantize_ideal(v: jnp.ndarray, bits: int, vdd: float = 1.0) -> jnp.ndarray:
    """Ideal mid-tread staircase: code k covers [k*LSB, (k+1)*LSB)."""
    n = 1 << bits
    return jnp.clip(jnp.floor(v / vdd * n), 0, n - 1).astype(jnp.int32)


def dequantize(codes: jnp.ndarray, bits: int, vdd: float = 1.0) -> jnp.ndarray:
    """Mid-point reconstruction of the code's voltage bin."""
    n = 1 << bits
    return (codes.astype(jnp.float32) + 0.5) * (vdd / n)


# ---------------------------------------------------------------------------
# Tree table helpers
# ---------------------------------------------------------------------------


def _tree_to_jnp(tree: st.TreeTables):
    return (
        jnp.asarray(tree.threshold),
        jnp.asarray(tree.left),
        jnp.asarray(tree.right),
        int(tree.max_depth),
    )


def stack_trees(trees: Sequence[st.TreeTables]):
    """Pad + stack per-segment trees (hybrid fine phase) into (S, n) tables."""
    n_int = max(max(t.threshold.size, 1) for t in trees)
    thr = np.zeros((len(trees), n_int), np.int32)
    left = np.full((len(trees), n_int), -1, np.int32)
    right = np.full((len(trees), n_int), -1, np.int32)
    for s, t in enumerate(trees):
        k = t.threshold.size
        thr[s, :k] = t.threshold
        left[s, :k] = t.left
        right[s, :k] = t.right
    max_depth = max(t.max_depth for t in trees)
    return jnp.asarray(thr), jnp.asarray(left), jnp.asarray(right), max_depth


# ---------------------------------------------------------------------------
# Traversal engine (vectorized, jit-friendly)
# ---------------------------------------------------------------------------


def _traverse(
    v: jnp.ndarray,
    ladder: jnp.ndarray,
    thr: jnp.ndarray,
    left: jnp.ndarray,
    right: jnp.ndarray,
    max_depth: int,
    sigma: float,
    key: Optional[jax.Array],
    boundary_offset: Optional[jnp.ndarray] = None,
    seg: Optional[jnp.ndarray] = None,
):
    """Walk an alphabetic search tree for every element of ``v`` in lockstep.

    ``thr/left/right`` are flat ``(n,)`` tables, or ``(S, n)`` segmented tables
    indexed by ``seg`` (hybrid fine phase). ``boundary_offset`` shifts the
    code-boundary index (per element) before the ladder lookup.
    """
    if max_depth == 0:
        z = jnp.zeros(v.shape, jnp.int32)
        return z, z

    ref = jnp.zeros(v.shape, jnp.int32)
    ncmp = jnp.zeros(v.shape, jnp.int32)
    if sigma > 0.0:
        if key is None:
            raise ValueError("comparator noise requires a PRNG key")
        noise = sigma * jax.random.normal(key, (max_depth,) + v.shape)
    else:
        noise = jnp.zeros((max_depth,) + v.shape)

    segmented = thr.ndim == 2

    def lookup(table, node):
        if segmented:
            return table[seg, node]
        return table[node]

    def body(i, state):
        ref, ncmp = state
        is_internal = ref >= 0
        node = jnp.maximum(ref, 0)
        t = lookup(thr, node)
        if boundary_offset is not None:
            t = t + boundary_offset
        go_right = (v + noise[i]) >= ladder[t]
        nxt = jnp.where(go_right, lookup(right, node), lookup(left, node))
        ref = jnp.where(is_internal, nxt, ref)
        ncmp = ncmp + is_internal.astype(jnp.int32)
        return ref, ncmp

    ref, ncmp = lax.fori_loop(0, max_depth, body, (ref, ncmp))
    codes = -ref - 1
    return codes, ncmp


# ---------------------------------------------------------------------------
# Conversion front-ends
# ---------------------------------------------------------------------------


def convert(
    v: jnp.ndarray,
    cfg: ADCConfig,
    key: Optional[jax.Array] = None,
    tree: Optional[st.TreeTables] = None,
    ladder: Optional[jnp.ndarray] = None,
    fine_trees: Optional[Sequence[st.TreeTables]] = None,
) -> ADCResult:
    """Digitize analog MAV voltages ``v`` under the configured mode.

    ``tree`` supplies the asymmetric search tree for ``sar_asym``;
    ``fine_trees`` optionally supplies 2^flash_bits per-segment asymmetric
    trees for the hybrid fine phase. ``ladder`` overrides reference
    generation (e.g. to reuse one mismatch draw across conversions).
    """
    v = jnp.asarray(v)
    mismatch_key = cmp_key = None
    if key is not None:
        mismatch_key, cmp_key = jax.random.split(key)
    if ladder is None:
        ladder = make_reference_ladder(cfg, mismatch_key)

    if cfg.mode == "ideal":
        codes = quantize_ideal(v, cfg.bits, cfg.vdd)
        z = jnp.zeros(v.shape, jnp.int32)
        return ADCResult(codes, z, z)

    if cfg.mode == "flash":
        n = cfg.n_codes
        if cfg.comparator_sigma > 0.0:
            if cmp_key is None:
                raise ValueError("comparator noise requires a PRNG key")
            noise = cfg.comparator_sigma * jax.random.normal(
                cmp_key, (n - 1,) + v.shape
            )
        else:
            noise = jnp.zeros((n - 1,) + v.shape)
        thrs = ladder[1:n]  # boundaries 1..n-1
        fired = (v[None] + noise) >= thrs.reshape((n - 1,) + (1,) * v.ndim)
        codes = fired.sum(axis=0).astype(jnp.int32)
        cmp = jnp.full(v.shape, n - 1, jnp.int32)
        cyc = jnp.ones(v.shape, jnp.int32)
        return ADCResult(codes, cmp, cyc)

    if cfg.mode in ("sar", "sar_asym"):
        if cfg.mode == "sar" or tree is None:
            tree = tree or st.symmetric_tree(cfg.bits)
        thr, left, right, max_depth = _tree_to_jnp(tree)
        codes, ncmp = _traverse(
            v, ladder, thr, left, right, max_depth, cfg.comparator_sigma, cmp_key
        )
        return ADCResult(codes, ncmp, ncmp)

    # hybrid: flash on the top flash_bits, then SAR within the segment
    f = cfg.flash_bits
    n_seg = 1 << f
    seg_size = 1 << (cfg.bits - f)
    coarse_boundaries = np.arange(1, n_seg) * seg_size  # ladder indices
    k1 = k2 = None
    if cmp_key is not None:
        k1, k2 = jax.random.split(cmp_key)
    if cfg.comparator_sigma > 0.0:
        noise = cfg.comparator_sigma * jax.random.normal(
            k1, (n_seg - 1,) + v.shape
        )
    else:
        noise = jnp.zeros((n_seg - 1,) + v.shape)
    cthr = ladder[jnp.asarray(coarse_boundaries)]
    fired = (v[None] + noise) >= cthr.reshape((n_seg - 1,) + (1,) * v.ndim)
    seg = fired.sum(axis=0).astype(jnp.int32)

    if fine_trees is not None:
        if len(fine_trees) != n_seg:
            raise ValueError(f"need {n_seg} fine trees, got {len(fine_trees)}")
        thr, left, right, max_depth = stack_trees(fine_trees)
    else:
        t = st.symmetric_tree(cfg.bits - f)
        thr, left, right, max_depth = _tree_to_jnp(t)
    fine_codes, fine_cmp = _traverse(
        v,
        ladder,
        thr,
        left,
        right,
        max_depth,
        cfg.comparator_sigma,
        k2,
        boundary_offset=seg * seg_size,
        seg=seg if fine_trees is not None else None,
    )
    codes = seg * seg_size + fine_codes
    comparisons = (n_seg - 1) + fine_cmp  # every flash comparator fires
    cycles = 1 + fine_cmp  # flash phase is one cycle
    return ADCResult(codes, comparisons, cycles)


# ---------------------------------------------------------------------------
# Static characterization (paper Fig. 6): staircase, DNL, INL
# ---------------------------------------------------------------------------


def measure_transfer(
    cfg: ADCConfig,
    key: Optional[jax.Array] = None,
    n_points: int = 8192,
    tree: Optional[st.TreeTables] = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Sweep a voltage ramp; return (ramp voltages, output codes)."""
    ramp = jnp.linspace(0.0, cfg.vdd * (1 - 1e-6), n_points)
    res = convert(ramp, cfg, key=key, tree=tree)
    return np.asarray(ramp), np.asarray(res.codes)


def dnl_inl(
    ramp: np.ndarray, codes: np.ndarray, cfg: ADCConfig
) -> tuple[np.ndarray, np.ndarray]:
    """Differential/integral non-linearity in LSB from a measured staircase."""
    n = cfg.n_codes
    lsb = cfg.lsb
    edges = np.full(n, np.nan)
    for c in range(1, n):
        idx = np.argmax(codes >= c)
        if codes[idx] >= c:
            edges[c] = ramp[idx]
    widths = np.diff(edges[1:])  # widths of codes 1..n-2
    dnl = widths / lsb - 1.0
    ideal_edges = np.arange(1, n) * lsb
    inl = (edges[1:] - ideal_edges) / lsb
    return dnl, inl
