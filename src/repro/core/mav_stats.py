"""MAV (multiply-average) statistics of bit-plane CiM arrays (paper Fig. 4a).

Under single-ended 8T processing, a column discharges only when stored bit AND
input bit are both '1'. With i.i.d. Bernoulli(p_w) weight bits and
Bernoulli(p_x) input bits, the number of discharging rows is
Binomial(R, p_w * p_x) and MAV = count / R — strongly skewed toward 0
(p = 0.25 for uniform bits). ReLU sparsity and weight regularization skew it
further. These distributions seed the asymmetric search tree.
"""

from __future__ import annotations

import numpy as np
from repro.core.scipy_free_stats import binom_pmf

__all__ = [
    "binom_pmf",
    "analytic_mav_pmf",
    "code_pmf_from_mav",
    "analytic_code_pmf",
    "empirical_code_pmf",
    "entropy_bits",
]


def analytic_mav_pmf(rows: int, p_discharge: float = 0.25) -> np.ndarray:
    """PMF over MAV levels k/rows, k = 0..rows (Binomial model)."""
    return binom_pmf(rows, p_discharge)


def code_pmf_from_mav(mav_pmf: np.ndarray, rows: int, bits: int) -> np.ndarray:
    """Push the MAV level distribution through the ideal B-bit quantizer."""
    n = 1 << bits
    pmf = np.zeros(n)
    for k, p in enumerate(mav_pmf):
        v = k / rows
        code = min(int(np.floor(v * n)), n - 1)
        pmf[code] += p
    return pmf


def analytic_code_pmf(rows: int = 16, bits: int = 5, p_discharge: float = 0.25):
    return code_pmf_from_mav(analytic_mav_pmf(rows, p_discharge), rows, bits)


def empirical_code_pmf(samples: np.ndarray, bits: int, vdd: float = 1.0):
    """Code histogram from observed MAV voltage samples (calibration path)."""
    n = 1 << bits
    codes = np.clip(np.floor(np.asarray(samples) / vdd * n), 0, n - 1).astype(int)
    pmf = np.bincount(codes, minlength=n).astype(np.float64)
    s = pmf.sum()
    return pmf / s if s > 0 else np.full(n, 1.0 / n)


def entropy_bits(pmf: np.ndarray) -> float:
    p = np.asarray(pmf, dtype=np.float64)
    p = p[p > 0]
    return float(-(p * np.log2(p)).sum())
