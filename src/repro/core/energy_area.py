"""Analytic area / energy / latency models (paper Table I, Fig. 7a,b).

Anchored to the paper's measured numbers and the reference ADC survey [19]:

  ===============  ======  ===========  ========
  Architecture      Tech    Area (µm²)   Energy (pJ), 5-bit @ 10 MHz
  ===============  ======  ===========  ========
  SAR   [19]        40 nm   5235.20      105
  Flash [19]        40 nm   10703.36     952
  In-memory (ours)  65 nm   207.8        74.23
  ===============  ======  ===========  ========

Scaling rules used for the design-space curves (standard first-order models):
  * SAR:   area ~ binary-weighted cap DAC (∝ 2^B) + B·logic; latency B cycles;
           energy ~ DAC switching (∝ 2^B·V²) + B comparator firings.
  * Flash: area/energy ∝ (2^B − 1) comparators + ladder; latency 1 cycle.
  * In-memory: the DAC *is* the neighbor array's parasitic bit lines → area is
           one comparator + precharge/transmission gates, nearly flat in B;
           latency B cycles (SAR), 1 (flash coupling), 1 + (B−f) (hybrid), or
           the expected asymmetric-search depth.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import search_tree as st
from repro.core.mav_stats import analytic_code_pmf

__all__ = [
    "ADC_STYLES",
    "area_um2",
    "energy_pj",
    "latency_cycles",
    "table1",
    "design_space",
]

_ANCHOR_BITS = 5

# Measured anchors at 5 bits.
_AREA_ANCHOR = {"sar": 5235.20, "flash": 10703.36, "in_memory": 207.8}
_ENERGY_ANCHOR = {"sar": 105.0, "flash": 952.0, "in_memory": 74.23}
_TECH = {"sar": "40nm", "flash": "40nm", "in_memory": "65nm"}

ADC_STYLES = (
    "sar",
    "flash",
    "in_memory",
    "in_memory_hybrid",
    "in_memory_asym",
    "in_memory_flash",
)


def _style_base(style: str) -> str:
    return "in_memory" if style.startswith("in_memory") else style


def area_um2(style: str, bits: int = 5) -> float:
    """ADC area vs precision, anchored at the 5-bit measured points."""
    base = _style_base(style)
    a5 = _AREA_ANCHOR[base]
    if base == "sar":
        # cap-DAC (2^B unit caps) dominates; ~15% fixed comparator+logic
        dac5, fixed = 0.85 * a5, 0.15 * a5
        return fixed * (bits / _ANCHOR_BITS) + dac5 * (2.0**bits / 2.0**_ANCHOR_BITS)
    if base == "flash":
        # 2^B − 1 comparators + encoder
        return a5 * (2.0**bits - 1.0) / (2.0**_ANCHOR_BITS - 1.0)
    # in-memory: comparator + precharge array control; control grows ~linearly
    fixed, per_bit = 0.80 * a5, 0.04 * a5
    return fixed + per_bit * bits


def latency_cycles(
    style: str,
    bits: int = 5,
    flash_bits: int = 2,
    pmf: Optional[np.ndarray] = None,
    rows: int = 16,
) -> float:
    """Conversion latency in comparison cycles (paper Fig. 7b)."""
    if style == "flash":
        return 1.0
    if style == "sar":
        return float(bits)
    if style == "in_memory":
        return float(bits)  # SAR-mode memory-immersed
    if style == "in_memory_flash":
        return 1.0  # one-to-many coupling: all references in parallel
    if style == "in_memory_hybrid":
        return 1.0 + (bits - flash_bits)
    if style == "in_memory_asym":
        if pmf is None:
            pmf = analytic_code_pmf(rows, bits)
        return st.optimal_tree(pmf).expected_depth(pmf)
    raise ValueError(style)


def energy_pj(
    style: str,
    bits: int = 5,
    freq_hz: float = 10e6,
    vdd: float = 1.0,
    flash_bits: int = 2,
    pmf: Optional[np.ndarray] = None,
    rows: int = 16,
    flash_share: int = 3,
) -> float:
    """Energy per conversion [pJ], anchored at the measured 5-bit points.

    ``flash_share``: in hybrid mode the Flash-phase references are generated
    once and shared among this many CiM arrays (paper §II-B), amortizing the
    reference-generation energy.
    """
    v2 = (vdd / 1.0) ** 2
    base = _style_base(style)
    if base == "sar":
        return _ENERGY_ANCHOR["sar"] * (bits / _ANCHOR_BITS) * v2
    if base == "flash":
        return (
            _ENERGY_ANCHOR["flash"]
            * (2.0**bits - 1.0)
            / (2.0**_ANCHOR_BITS - 1.0)
            * v2
        )
    # in-memory: per-cycle energy = comparator + neighbor-array reference
    # precharge. Anchor: 5 symmetric SAR cycles = 74.23 pJ.
    e_cycle = _ENERGY_ANCHOR["in_memory"] / _ANCHOR_BITS
    e_cmp, e_ref = 0.4 * e_cycle, 0.6 * e_cycle  # comparator / reference split
    if style == "in_memory":
        return bits * (e_cmp + e_ref) * v2
    if style == "in_memory_asym":
        cyc = latency_cycles(style, bits, pmf=pmf, rows=rows)
        return cyc * (e_cmp + e_ref) * v2
    if style == "in_memory_flash":
        # one comparison cycle; 2^B - 1 neighbor-array references precharged
        # in parallel, shared among `flash_share` compute arrays per bank
        n_ref = 2.0**bits - 1.0
        return n_ref * (e_cmp + e_ref / flash_share) * v2
    if style == "in_memory_hybrid":
        n_flash_ref = 2.0**flash_bits - 1.0
        # flash phase: n_flash_ref refs shared across `flash_share` arrays,
        # n_flash_ref comparator firings; SAR phase: (bits - flash_bits) cycles.
        e_flash = n_flash_ref * (e_ref / flash_share + e_cmp)
        e_sar = (bits - flash_bits) * (e_cmp + e_ref)
        return (e_flash + e_sar) * v2
    raise ValueError(style)


def table1() -> dict[str, dict]:
    """Reproduce paper Table I."""
    out = {}
    for style in ("sar", "flash", "in_memory"):
        out[style] = {
            "tech": _TECH[style],
            "area_um2": round(area_um2(style, 5), 2),
            "energy_pj": round(energy_pj(style, 5), 2),
        }
    return out


def design_space(bit_range=range(3, 9)) -> dict:
    """Area/latency/energy curves per style vs precision (Fig. 7a,b)."""
    out: dict = {}
    for style in ADC_STYLES:
        out[style] = {
            "bits": list(bit_range),
            "area_um2": [area_um2(style, b) for b in bit_range],
            "latency_cycles": [latency_cycles(style, b) for b in bit_range],
            "energy_pj": [energy_pj(style, b) for b in bit_range],
        }
    return out
