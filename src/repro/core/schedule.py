"""Collaborative digitization schedules among CiM arrays (paper Figs. 2, 3, 5c).

The paper's arrays alternate between *compute* (analog MAV) and *digitize*
(reference generation for a neighbor) roles. This module builds cycle-accurate
schedules for the three networking configurations and derives system-level
throughput/utilization — the quantities behind the paper's claim that the
halved per-array throughput is recovered by packing more arrays in the saved
ADC area.

Configurations:
  * ``pair_sar``    — arrays (A, B): A computes while B digitizes A's previous
                      MAV; roles swap each conversion (Fig. 2).
  * ``flash``       — 1-to-k coupling: k arrays generate 2^f − 1 references in
                      parallel; one comparison cycle per conversion (Fig. 1 right).
  * ``hybrid``      — Fig. 3/5c: CiM arrays take turns using the shared Flash
                      bank for their MSBs, then pair off for SAR on the rest.
"""

from __future__ import annotations

import dataclasses
from typing import List

__all__ = ["Slot", "ScheduleResult", "pair_sar_schedule", "hybrid_schedule", "throughput_summary"]


@dataclasses.dataclass(frozen=True)
class Slot:
    cycle: int
    array: str
    role: str  # compute | ref_gen | flash_ref | compare | idle


@dataclasses.dataclass
class ScheduleResult:
    slots: List[Slot]
    n_cycles: int
    n_conversions: int
    n_arrays: int

    @property
    def conversions_per_cycle_per_array(self) -> float:
        return self.n_conversions / (self.n_cycles * self.n_arrays)

    def utilization(self, role: str = "compute") -> float:
        busy = sum(1 for s in self.slots if s.role == role)
        return busy / (self.n_cycles * self.n_arrays)


def pair_sar_schedule(bits: int = 5, n_conversions: int = 4) -> ScheduleResult:
    """Two arrays alternating compute/digitize (Fig. 2a). One conversion =
    1 compute cycle + ``bits`` reference/compare cycles on the partner."""
    slots: List[Slot] = []
    cycle = 0
    for conv in range(n_conversions):
        computer, digitizer = ("A", "B") if conv % 2 == 0 else ("B", "A")
        slots.append(Slot(cycle, computer, "compute"))
        slots.append(Slot(cycle, digitizer, "idle"))
        cycle += 1
        for _ in range(bits):
            slots.append(Slot(cycle, digitizer, "ref_gen"))
            # the computing array holds V_MAV; comparator fires this cycle
            slots.append(Slot(cycle, computer, "hold"))
            cycle += 1
    return ScheduleResult(slots, cycle, n_conversions, 2)


def hybrid_schedule(
    bits: int = 5, flash_bits: int = 2, n_cim_arrays: int = 3
) -> ScheduleResult:
    """Fig. 3: ``n_cim_arrays`` compute arrays sequentially use a shared bank
    of 2^flash_bits − 1 reference arrays for their MSBs, then each pairs with
    the nearest reference array for SAR on the remaining bits (in parallel
    across arrays once freed)."""
    n_ref = (1 << flash_bits) - 1
    names_cim = [f"C{i}" for i in range(n_cim_arrays)]
    names_ref = [f"R{i}" for i in range(n_ref)]
    slots: List[Slot] = []
    cycle = 0
    # compute phase: all CiM arrays evaluate their MAV simultaneously
    for nm in names_cim:
        slots.append(Slot(cycle, nm, "compute"))
    for nm in names_ref:
        slots.append(Slot(cycle, nm, "flash_ref"))  # references precharge
    cycle += 1
    # flash phase: one comparison cycle per CiM array against the shared bank
    for i, nm in enumerate(names_cim):
        slots.append(Slot(cycle + i, nm, "compare"))
        for r in names_ref:
            slots.append(Slot(cycle + i, r, "flash_ref"))
    # SAR tails run in parallel, staggered by their flash slot
    sar_cycles = bits - flash_bits
    end = cycle
    for i, nm in enumerate(names_cim):
        start = cycle + i + 1
        ref = names_ref[i % n_ref]
        for c in range(sar_cycles):
            slots.append(Slot(start + c, nm, "hold"))
            slots.append(Slot(start + c, ref, "ref_gen"))
        end = max(end, start + sar_cycles)
    return ScheduleResult(slots, end, n_cim_arrays, n_cim_arrays + n_ref)


def throughput_summary(bits: int = 5, flash_bits: int = 2) -> dict:
    """System-level throughput comparison used in DESIGN/EXPERIMENTS.

    ``area_budget_ratio``: with a dedicated SAR ADC per array costing ~25x the
    in-memory digitizer (Table I), the ADC area of one conventional array
    funds ~the digitizer area of 25 collaborative arrays; even at half duty
    cycle the collaborative scheme nets >10x conversions per unit area.
    """
    pair = pair_sar_schedule(bits=bits, n_conversions=8)
    hyb = hybrid_schedule(bits=bits, flash_bits=flash_bits, n_cim_arrays=3)
    area_ratio = 5235.20 / 207.8
    return {
        "pair_sar_conv_per_cycle_per_array": pair.conversions_per_cycle_per_array,
        "hybrid_conv_per_cycle_per_array": hyb.conversions_per_cycle_per_array,
        "dedicated_adc_area_ratio": area_ratio,
        "conversions_per_area_gain": area_ratio
        * pair.conversions_per_cycle_per_array
        / (1.0 / (1 + bits)),
    }
