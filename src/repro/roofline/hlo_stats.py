"""Loop-aware per-device statistics from optimized (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts each while-loop body ONCE (verified
on this jax build), which under-counts scan-over-layers models by ~L×. This
module re-derives the roofline inputs directly from the HLO text with loop
trip-count multiplication:

  * ``dot_flops``       — 2·prod(result)·prod(contracting) per dot op,
                          × execution multiplicity. (MXU term — elementwise
                          FLOPs are excluded by design.)
  * ``op_bytes``        — fusion-granularity memory traffic: for each
                          top-level dot/fusion/copy/scatter/gather/... op,
                          operand + result bytes (fusion internals are free,
                          matching the TPU fusion cost model), × multiplicity.
  * ``collective_stats``— ring-model wire bytes per collective kind,
                          × multiplicity (used by analysis.collective_bytes).

Multiplicity: entry = 1; while bodies × trip count (largest integer constant
in the loop condition — scans lower to `iter < L` compares); fusion/call
bodies inherit the caller's multiplicity.
"""

from __future__ import annotations

import re
from typing import Dict, List

__all__ = ["HloStats", "analyze"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_OP_RE = re.compile(r"((?:\([^=]*?\))|(?:\S+))\s+([\w\-]+)\(")
_OPERANDS_RE = re.compile(r"\(([^)]*)\)")
_WHILE_RE = re.compile(r"condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}")
_GROUPS_ALT_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

# Ops whose operands/results are charged as HBM traffic. Pure elementwise ops
# (add/mul/exp/...) are EXCLUDED — on TPU, XLA fuses elementwise chains into
# their producers, so charging them would model CPU (unfused) behavior. What
# remains: matmuls, data movement, scatter/gather, reductions — the ops whose
# buffers genuinely round-trip HBM at fusion boundaries.
_BYTES_OPS = {
    "dot", "fusion", "copy", "scatter", "gather", "reduce", "sort",
    "dynamic-slice", "dynamic-update-slice", "convolution", "select-and-scatter",
    "reduce-window", "rng",
}
_SKIP_OPS = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "reshape", "while", "call", "conditional", "after-all", "custom-call",
    "partition-id", "replica-id", "optimization-barrier",
}
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute",
)


def _shape_elems_bytes(shape_str: str):
    total_b = 0
    dims_all: List[List[int]] = []
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        ds = [int(d) for d in dims.split(",")] if dims else []
        n = 1
        for d in ds:
            n *= d
        total_b += n * _DTYPE_BYTES[dt]
        dims_all.append(ds)
    return total_b, dims_all


class _Comp:
    def __init__(self, name: str, is_fusion: bool):
        self.name = name
        self.is_fusion = is_fusion
        self.lines: List[str] = []
        self.shapes: Dict[str, str] = {}  # op name -> result type string


def _split(hlo_text: str) -> Dict[str, _Comp]:
    comps: Dict[str, _Comp] = {}
    cur = None
    for line in hlo_text.splitlines():
        if line and not line[0].isspace() and "{" in line:
            hdr = line.strip()
            name = hdr.split()[0].lstrip("%")
            if hdr.startswith("ENTRY"):
                name = "__entry__"
            is_fusion = "fused" in name or "computation" in name and "region" not in name
            cur = _Comp(name, "fused" in name)
            comps[name] = cur
        elif cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                cur.lines.append(line)
                m = _DEF_RE.match(line)
                if m:
                    rest = m.group(2)
                    # result type = leading token(s) before the op name
                    om = _OP_RE.match(rest)
                    if om:
                        cur.shapes[m.group(1)] = om.group(1)
    return comps


def _trip_count(comp: _Comp) -> int:
    best = 1
    for line in comp.lines:
        for m in _CONST_RE.finditer(line):
            best = max(best, int(m.group(1)))
    return best


def _multiplicities(comps: Dict[str, _Comp]) -> Dict[str, float]:
    mult = {name: 0.0 for name in comps}
    if "__entry__" in comps:
        mult["__entry__"] = 1.0
    else:
        return {name: 1.0 for name in comps}
    for _ in range(16):
        changed = False
        for name, comp in comps.items():
            m = mult.get(name, 0.0)
            if m <= 0:
                continue
            for line in comp.lines:
                if " while(" in line:
                    w = _WHILE_RE.search(line)
                    if w:
                        cond, body = w.group(1), w.group(2)
                        trips = _trip_count(comps[cond]) if cond in comps else 1
                        for tgt, f in ((body, trips), (cond, trips + 1)):
                            if tgt in mult and m * f > mult[tgt]:
                                mult[tgt] = m * f
                                changed = True
                else:
                    for cm in _CALLS_RE.finditer(line):
                        tgt = cm.group(1)
                        if tgt in mult and m > mult[tgt]:
                            mult[tgt] = m
                            changed = True
        if not changed:
            break
    return mult


def _operand_names(line: str) -> List[str]:
    # operands are inside the first (...) after the op name
    m = re.search(r"[\w\-]+\((.*)\)", line)
    if not m:
        return []
    inner = m.group(1)
    names = re.findall(r"%([\w\.\-]+)", inner)
    return names


class HloStats:
    def __init__(self):
        self.dot_flops = 0.0
        self.op_bytes = 0.0
        self.collectives = {
            k: {"bytes": 0.0, "count": 0} for k in _COLLECTIVES
        }
        self.n_while = 0

    @property
    def collective_total(self) -> float:
        return sum(v["bytes"] for v in self.collectives.values())


def analyze(hlo_text: str, n_devices: int) -> HloStats:
    comps = _split(hlo_text)
    mult = _multiplicities(comps)
    st = HloStats()

    for name, comp in comps.items():
        m_exec = mult.get(name, 1.0)
        if m_exec <= 0:
            continue
        for line in comp.lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            rest = dm.group(2)
            om = _OP_RE.match(rest)
            if not om:
                continue
            result_type, op = om.group(1), om.group(2)
            base_op = op.replace("-start", "").replace("-done", "")

            if base_op == "while":
                st.n_while += 1

            # ---- dot flops (all computations, incl. fusion bodies)
            if op == "dot":
                rbytes, rdims = _shape_elems_bytes(result_type)
                res_elems = 1
                for d in (rdims[0] if rdims else []):
                    res_elems *= d
                # contracting dims from lhs shape
                lhs_names = _operand_names(line)
                cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
                k_prod = 1
                if cm and lhs_names:
                    lhs_type = comp.shapes.get(lhs_names[0], "")
                    _, ldims = _shape_elems_bytes(lhs_type)
                    if ldims:
                        for ci in cm.group(1).split(","):
                            if ci != "" and int(ci) < len(ldims[0]):
                                k_prod *= ldims[0][int(ci)]
                st.dot_flops += 2.0 * res_elems * k_prod * m_exec

            # ---- bytes (top-level non-fusion computations only)
            if not comp.is_fusion and base_op in _BYTES_OPS:
                rbytes, rdims_all = _shape_elems_bytes(result_type)
                rdims = rdims_all[0] if rdims_all else []
                op_infos = []
                for on in _operand_names(line):
                    t = comp.shapes.get(on)
                    if t:
                        b, d = _shape_elems_bytes(t)
                        op_infos.append((b, d[0] if d else []))
                obytes = sum(b for b, _ in op_infos)
                # In-place-update pattern (dynamic-update-slice, or a fusion
                # wrapping one — scan stacking a per-layer slice into the
                # (L, ...) buffer): TPU aliases the big buffer; only the slice
                # round-trips HBM. Conditions: (a) an operand with the exact
                # result size (the buffer), (b) an operand that is a same-rank
                # STRICT slice of the result (the update) — a broadcastable
                # scale/bias operand does NOT qualify, so genuine elementwise
                # fusions stay fully charged.
                if base_op in ("dynamic-update-slice", "fusion") and rbytes > (1 << 20):
                    has_alias = any(b == rbytes for b, _ in op_infos)

                    def _is_slice(d):
                        return (
                            len(d) == len(rdims)
                            and all(x <= y for x, y in zip(d, rdims))
                            and any(x < y for x, y in zip(d, rdims))
                        )

                    slices = [b for b, d in op_infos if b < rbytes and _is_slice(d)]
                    if has_alias and slices:
                        st.op_bytes += 2.0 * sum(slices) * m_exec
                        continue
                if base_op == "dynamic-slice":
                    # reads one slice, not the whole operand
                    st.op_bytes += 2.0 * rbytes * m_exec
                    continue
                # Stacked-buffer slice READ (fusion wrapping a dynamic-slice
                # of the scan-saved (L, ...) residuals): charge the stacked
                # operand at one slice, as the TPU dynamic-slice would.
                if base_op == "fusion" and rdims:
                    adj = 0
                    for b, d in op_infos:
                        if (
                            len(d) == len(rdims) + 1
                            and list(d[1:]) == list(rdims)
                            and b > rbytes
                        ):
                            adj += b - rbytes
                    if adj:
                        st.op_bytes += (rbytes + obytes - adj) * m_exec
                        continue
                st.op_bytes += (rbytes + obytes) * m_exec

            # ---- collectives
            if base_op in _COLLECTIVES and "-done" not in op:
                nbytes, _ = _shape_elems_bytes(result_type)
                d = n_devices
                g = _GROUPS_ALT_RE.search(line)
                if g:
                    d = int(g.group(2))
                else:
                    g = _GROUPS_RE.search(line)
                    if g:
                        first = g.group(1).split("}")[0]
                        ids = [t for t in first.replace("{", "").split(",") if t.strip()]
                        d = max(len(ids), 1)
                if d <= 1:
                    continue
                ring = (d - 1) / d
                if base_op == "all-gather":
                    wire = ring * nbytes
                elif base_op == "all-reduce":
                    wire = 2.0 * ring * nbytes
                elif base_op == "reduce-scatter":
                    wire = ring * nbytes * d
                elif base_op == "all-to-all":
                    wire = ring * nbytes
                else:
                    wire = float(nbytes)
                st.collectives[base_op]["bytes"] += wire * m_exec
                st.collectives[base_op]["count"] += int(m_exec)
    return st
