"""Roofline analysis from compiled dry-run artifacts."""

from repro.roofline.analysis import RooflineReport, model_flops, roofline

__all__ = ["RooflineReport", "model_flops", "roofline"]
