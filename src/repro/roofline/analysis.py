"""Three-term roofline from the compiled dry-run artifact.

  compute    = dot_FLOPs_per_device    / PEAK_FLOPS
  memory     = op_bytes_per_device     / HBM_BW
  collective = wire_bytes_per_device   / ICI_LINK_BW

All three numerators come from the loop-aware HLO analyzer
(roofline/hlo_stats.py): XLA's ``cost_analysis()`` counts while-loop bodies
once (verified), so scan-over-layers models need explicit trip-count
multiplication. Semantics:

  * dot_FLOPs — MXU matmul flops only (elementwise excluded): the right
    numerator against the MXU peak.
  * op_bytes — fusion-granularity operand+result bytes (fusion internals
    free), the TPU fusion cost model applied to the CPU-partitioned HLO.
  * wire bytes — bandwidth-optimal-ring model per collective kind:
      all-gather   (D-1)/D × full buffer     reduce-scatter (D-1)/D × full
      all-reduce 2·(D-1)/D × buffer          all-to-all     (D-1)/D × buffer
      collective-permute 1 × buffer
    (D = replica-group size parsed per op.)

``cost_analysis`` numbers are retained in the report for reference.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.roofline import hw
from repro.roofline.hlo_stats import HloStats, analyze

__all__ = ["roofline", "RooflineReport", "model_flops", "analyze", "flash_kernel_flops"]


def flash_kernel_flops(cfg, shape) -> float:
    """Analytic per-device dot FLOPs executed INSIDE the flash-attention
    kernel (perf iteration D): Pallas-internal dots under a dynamic
    (causality-skipping) loop bound are not visible to the HLO trip-count
    parser. Causal: 2 × (qk + pv) × 0.5 = 2·B·S²·h·hd per attention layer.
    """
    if getattr(cfg, "attn_impl", "blocked") != "flash" or not cfg.n_heads:
        return 0.0
    if shape.kind == "train":
        passes = 3.0  # fwd + bwd(2x) — not used: flash is fwd-only today
    else:
        passes = 1.0
    n_attn = cfg.n_layers if cfg.family != "hybrid" else cfg.n_layers // max(cfg.share_period, 1)
    b, s = shape.global_batch, shape.seq_len
    return passes * 2.0 * b * s * s * cfg.n_heads * cfg.head_dim * n_attn


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    n_devices: int
    flops_per_device: float  # loop-aware dot flops
    bytes_per_device: float  # loop-aware fusion-level bytes
    wire_bytes_per_device: float
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float
    useful_ratio: float  # MODEL_FLOPS / (flops_per_device * n_devices)
    collectives: dict
    xla_cost_flops: Optional[float] = None  # raw cost_analysis (loop-unaware)
    xla_cost_bytes: Optional[float] = None
    peak_memory_per_device: Optional[float] = None

    def to_dict(self):
        return dataclasses.asdict(self)

    @property
    def roofline_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def roofline_fraction(self) -> float:
        """useful-FLOPs time / binding-roofline time: the fraction of the
        roofline-limited step that does model math."""
        t_useful = (self.model_flops / self.n_devices) / hw.PEAK_FLOPS_BF16
        return t_useful / self.roofline_time if self.roofline_time > 0 else 0.0


def model_flops(cfg, shape) -> float:
    """Reference useful FLOPs per step: 6·N_active·tokens (train),
    2·N_active·tokens (prefill/decode)."""
    n = cfg.n_active_params()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: 1 token per sequence


def roofline(
    arch: str,
    shape,
    cfg,
    cost: dict,
    hlo_text: str,
    n_devices: int,
    memory_stats: Optional[dict] = None,
) -> RooflineReport:
    st: HloStats = analyze(hlo_text, n_devices)
    flops = st.dot_flops + flash_kernel_flops(cfg, shape) / n_devices
    nbytes = st.op_bytes
    wire = st.collective_total

    t_c = flops / hw.PEAK_FLOPS_BF16
    t_m = nbytes / hw.HBM_BW
    t_x = wire / hw.ICI_LINK_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)

    mf = model_flops(cfg, shape)
    useful = mf / (flops * n_devices) if flops > 0 else 0.0

    return RooflineReport(
        arch=arch,
        shape=shape.name,
        n_devices=n_devices,
        flops_per_device=flops,
        bytes_per_device=nbytes,
        wire_bytes_per_device=wire,
        t_compute=t_c,
        t_memory=t_m,
        t_collective=t_x,
        bottleneck=bottleneck,
        model_flops=mf,
        useful_ratio=useful,
        collectives=st.collectives,
        xla_cost_flops=float(cost.get("flops", 0.0)) if cost else None,
        xla_cost_bytes=float(cost.get("bytes accessed", 0.0)) if cost else None,
        peak_memory_per_device=(memory_stats or {}).get("bytes"),
    )
