"""Render the EXPERIMENTS.md roofline tables from cached dry-run JSON.

  PYTHONPATH=src python -m repro.roofline.report [--dir results/dryrun]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path


def _fmt_t(s: float) -> str:
    if s >= 1.0:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s * 1e3:.1f}ms"
    return f"{s * 1e6:.0f}us"


def load(dir_: Path, mesh: str):
    recs = []
    for f in sorted(dir_.glob(f"*__{mesh}.json")):
        r = json.loads(f.read_text())
        recs.append(r)
    return recs


def next_lever(rec) -> str:
    """One sentence: what would move the dominant term down (per assignment)."""
    rf = rec["roofline"]
    arch, shape, b = rec["arch"], rec["shape"], rf["bottleneck"]
    is_moe = "moe" in arch or "moonshot" in arch
    is_ssm = arch.startswith(("mamba", "zamba"))
    if b == "collective":
        if is_moe:
            return "eliminate MoE dispatch gathers (dense-masked experts, iter B1)"
        return "reduce TP activation psums: bf16 boundary dtypes + overlap via latency-hiding scheduler"
    if b == "memory":
        if "decode" in shape or "long" in shape:
            return "int8 KV cache + int8 weight dots (iters C1/C2) cut the dominant cache/weight reads"
        if is_ssm:
            return "fuse the SSD chunk pipeline (Pallas) so decay/state tensors stay in VMEM"
        if "prefill" in shape:
            return "fused flash-attention kernel keeps score tiles in VMEM (kernels/flash_attention.py)"
        return "bf16 materialization + chunk-remat (iters A1c/A2/A3); next: fused attention kernel"
    return "raise arithmetic intensity: larger per-device batch or wider TP sharding of heads"


def roofline_table(recs) -> str:
    hdr = (
        "| arch | shape | t_compute | t_memory | t_collective | bottleneck | "
        "mem/dev | MODEL/HLO flops | roofline frac | top collective | what would move the dominant term |\n"
        "|---|---|---|---|---|---|---|---|---|---|---|\n"
    )
    rows = []
    for r in recs:
        if r.get("status") != "ok":
            rows.append(
                f"| {r['arch']} | {r['shape']} | FAIL | | | | | | | {r.get('error','')[:40]} | |"
            )
            continue
        rf = r["roofline"]
        colls = {
            k: v["bytes"]
            for k, v in rf["collectives"].items()
            if isinstance(v, dict) and v["bytes"] > 0
        }
        top = max(colls, key=colls.get) if colls else "-"
        tops = f"{top} {colls[top]/2**30:.1f}GiB" if colls else "-"
        rows.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_t(rf['t_compute'])} | "
            f"{_fmt_t(rf['t_memory'])} | {_fmt_t(rf['t_collective'])} | "
            f"{rf['bottleneck']} | {r['memory']['bytes']/2**30:.2f}GiB | "
            f"{rf['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} | {tops} | "
            f"{next_lever(r)} |"
        )
    return hdr + "\n".join(rows) + "\n"


def collective_schedule(recs, picks) -> str:
    """Per-cell collective op counts/bytes by kind (the collective schedule)."""
    out = [
        "| arch | shape | all-gather | all-reduce | reduce-scatter | all-to-all | permute |",
        "|---|---|---|---|---|---|---|",
    ]
    for arch, shape in picks:
        rec = next(
            (r for r in recs if r["arch"] == arch and r["shape"] == shape and r.get("status") == "ok"),
            None,
        )
        if rec is None:
            continue
        c = rec["roofline"]["collectives"]
        cell = lambda k: f"{c[k]['count']}× / {c[k]['bytes']/2**30:.1f}GiB"
        out.append(
            f"| {arch} | {shape} | {cell('all-gather')} | {cell('all-reduce')} | "
            f"{cell('reduce-scatter')} | {cell('all-to-all')} | {cell('collective-permute')} |"
        )
    return "\n".join(out)


def summary(recs) -> dict:
    ok = [r for r in recs if r.get("status") == "ok"]
    worst = sorted(ok, key=lambda r: r["roofline_fraction"])[:5]
    coll = sorted(
        ok,
        key=lambda r: -(
            r["roofline"]["t_collective"]
            / max(r["roofline"]["t_compute"] + r["roofline"]["t_memory"], 1e-12)
        ),
    )[:5]
    return {
        "n_ok": len(ok),
        "n_fail": len(recs) - len(ok),
        "worst_fraction": [(r["arch"], r["shape"], round(r["roofline_fraction"], 4)) for r in worst],
        "most_collective_bound": [
            (r["arch"], r["shape"], round(r["roofline"]["t_collective"], 3)) for r in coll
        ],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="singlepod")
    args = ap.parse_args()
    recs = load(Path(args.dir), args.mesh)
    print(roofline_table(recs))
    print(json.dumps(summary(recs), indent=2))


if __name__ == "__main__":
    main()
