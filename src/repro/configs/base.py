"""Model / training configuration schema.

One ``ModelConfig`` describes any architecture in the assigned pool
(dense / GQA / MoE / SSM / hybrid decoder LMs, plus modality-stub backbones).
Configs are plain frozen dataclasses — hashable, jit-static-friendly.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.cim_linear import CiMConfig

__all__ = ["ModelConfig", "ShapeConfig", "reduced"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | mamba | hybrid
    n_layers: int
    d_model: int
    vocab: int
    # attention (ignored for family == mamba)
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 128
    d_ff: int = 0
    qkv_bias: bool = False
    rope_theta: float = 1e6
    sliding_window: Optional[int] = None  # cap attention span (zamba2 long ctx)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    moe_impl: str = "scatter"  # scatter (GShard dispatch) | dense (masked, collective-minimal)
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    # hybrid (zamba2): one shared-weight attention block applied every
    # `share_period` mamba layers
    share_period: int = 0
    # embedding / head
    tie_embeddings: bool = False
    input_kind: str = "tokens"  # tokens | embeddings (modality-frontend stub)
    pad_vocab_multiple: int = 256
    norm_eps: float = 1e-5
    # numerics / execution
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: str = "full"  # none | full
    attn_chunk: int = 1024  # KV-chunk for blocked attention
    attn_impl: str = "blocked"  # blocked | flash (fused Pallas kernel; fwd-only paths)
    loss_chunk: int = 512  # sequence-chunk for the unembed/softmax-xent
    optimizer: str = "adamw"  # adamw | adafactor
    # the paper's technique: CiM quantization applied to linears (None = off)
    cim: Optional[CiMConfig] = None
    kv_quant_int8: bool = False  # int8 KV cache for serving (perf iter C2)
    # notes for DESIGN/EXPERIMENTS (e.g. long-context applicability)
    subquadratic: bool = False  # supports long_500k decode

    @property
    def padded_vocab(self) -> int:
        m = self.pad_vocab_multiple
        return ((self.vocab + m - 1) // m) * m

    @property
    def d_inner(self) -> int:  # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def n_params(self) -> int:
        """Total parameter count (analytic)."""
        d, f, v = self.d_model, self.d_ff, self.padded_vocab
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_attn = d * self.n_heads * self.head_dim + 2 * d * self.n_kv_heads * self.head_dim + self.n_heads * self.head_dim * d
        per_mlp = 3 * d * f
        per_moe = d * self.n_experts + 3 * self.n_experts * d * self.d_ff_expert if self.n_experts else 0
        per_mamba = 0
        if self.ssm_state:
            di, h, ns = self.d_inner, self.ssm_heads, self.ssm_state
            zxbcdt = 2 * di + 2 * ns + h
            per_mamba = d * zxbcdt + (di + 2 * ns) * self.ssm_conv_width + 3 * h + di * d + di
        if self.family == "dense":
            body = self.n_layers * (per_attn + per_mlp + 2 * d)
        elif self.family == "moe":
            body = self.n_layers * (per_attn + per_moe + 2 * d)
        elif self.family == "mamba":
            body = self.n_layers * (per_mamba + d)
        elif self.family == "hybrid":
            n_shared = self.n_layers // max(self.share_period, 1)
            body = self.n_layers * (per_mamba + d) + (per_attn + per_mlp + 2 * d)
        else:
            raise ValueError(self.family)
        return emb + body + d  # final norm

    def n_active_params(self) -> int:
        """Active parameters per token (MoE routes top_k of n_experts)."""
        if not self.n_experts:
            return self.n_params()
        d = self.d_model
        per_moe_total = 3 * self.n_experts * d * self.d_ff_expert
        per_moe_active = 3 * self.top_k * d * self.d_ff_expert
        return self.n_params() - self.n_layers * (per_moe_total - per_moe_active)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Small same-family config for CPU smoke tests."""
    base = dict(
        n_layers=2,
        d_model=64,
        vocab=256,
        head_dim=16,
        rope_theta=1e4,
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
        attn_chunk=64,
        loss_chunk=64,
        pad_vocab_multiple=16,
    )
    if cfg.n_heads:
        base.update(n_heads=4, n_kv_heads=max(1, 4 * cfg.n_kv_heads // max(cfg.n_heads, 1)), d_ff=128)
    if cfg.n_experts:
        base.update(n_experts=8, top_k=2, d_ff_expert=32)
    if cfg.ssm_state:
        base.update(ssm_state=16, ssm_headdim=16, ssm_chunk=32)
    if cfg.share_period:
        base.update(share_period=2, n_layers=5, n_heads=4, n_kv_heads=4, d_ff=128)
    base.update(overrides)
    return dataclasses.replace(cfg, **base)
