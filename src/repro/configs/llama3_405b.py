"""llama3-405b [dense]: GQA, 128k vocab. Adafactor optimizer (Adam moments at
405B would not fit the single-pod HBM budget; see EXPERIMENTS.md §Dry-run).
[arXiv:2407.21783; unverified]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    head_dim=128,
    d_ff=53248,
    vocab=128256,
    rope_theta=5e5,
    optimizer="adafactor",
)
