"""pixtral-12b [vlm]: Pixtral-ViT frontend (STUB: precomputed patch
embeddings) + Mistral-Nemo-style 40L decoder backbone.
[hf:mistralai/Pixtral-12B-2409; unverified]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=131072,
    rope_theta=1e6,
    input_kind="embeddings",  # modality frontend stub provides (B, S, D)
)
