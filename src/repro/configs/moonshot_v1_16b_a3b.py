"""moonshot-v1-16b-a3b [moe]: kimi/moonlight, 64 experts top-6.
[hf:moonshotai/Moonlight-16B-A3B; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    n_experts=64,
    top_k=6,
    d_ff_expert=1408,
    vocab=163840,
    rope_theta=5e4,
    moe_impl="dense",  # perf iteration B1 (EXPERIMENTS.md §Perf)
)
