"""Architecture configs (assigned pool) + shape suites."""

from repro.configs.base import ModelConfig, ShapeConfig, reduced
from repro.configs.registry import ARCHS, for_shape, get_config
from repro.configs.shapes import SHAPES, all_cells, valid_cells

__all__ = [
    "ModelConfig",
    "ShapeConfig",
    "reduced",
    "ARCHS",
    "get_config",
    "for_shape",
    "SHAPES",
    "all_cells",
    "valid_cells",
]
