"""mamba2-130m [ssm]: SSD (state-space duality), attention-free.
[arXiv:2405.21060; unverified]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="mamba",
    n_layers=24,
    d_model=768,
    vocab=50280,
    ssm_state=128,
    ssm_headdim=64,
    tie_embeddings=True,
    subquadratic=True,
)
