"""musicgen-medium [audio]: decoder-only over EnCodec tokens (frontend STUB:
token ids over the 2048-entry codebook). MHA (kv == heads). RoPE replaces the
original learned positions (deviation noted in DESIGN.md).
[arXiv:2306.05284; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="dense",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab=2048,
    rope_theta=1e4,
    pad_vocab_multiple=256,
)
