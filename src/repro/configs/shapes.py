"""Assigned input-shape suites (seq_len x global_batch per kind)."""

from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeConfig

__all__ = ["SHAPES", "valid_cells", "all_cells"]

SHAPES = {
    "train_4k": ShapeConfig("train_4k", seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": ShapeConfig("prefill_32k", seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": ShapeConfig("decode_32k", seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": ShapeConfig("long_500k", seq_len=524288, global_batch=1, kind="decode"),
}


def valid_cells(cfg: ModelConfig) -> list[str]:
    """Shape names applicable to this architecture. long_500k requires
    sub-quadratic attention (SSM / hybrid) per the assignment."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.subquadratic:
        names.append("long_500k")
    return names


def all_cells(configs: dict) -> list[tuple[str, str]]:
    out = []
    for name, cfg in configs.items():
        for sh in valid_cells(cfg):
            out.append((name, sh))
    return out
