"""zamba2-7b [hybrid]: 81 Mamba2 layers + one shared-weight attention block
applied every 6 layers (13 applications). Long-context cells cap the shared
attention with a 4096 sliding window (applied by registry.for_shape).
[arXiv:2411.15242; unverified]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab=32000,
    ssm_state=64,
    ssm_headdim=64,
    share_period=6,
    rope_theta=1e4,
    subquadratic=True,
)
