"""Architecture registry: --arch <id> lookup + shape-specific overrides."""

from __future__ import annotations

import dataclasses

from repro.configs import (
    command_r_plus_104b,
    llama3_405b,
    mamba2_130m,
    moonshot_v1_16b_a3b,
    musicgen_medium,
    pixtral_12b,
    qwen2_5_32b,
    qwen3_moe_30b_a3b,
    smollm_135m,
    zamba2_7b,
)
from repro.configs.base import ModelConfig
from repro.configs.shapes import SHAPES, ShapeConfig

ARCHS: dict[str, ModelConfig] = {
    c.CONFIG.name: c.CONFIG
    for c in (
        pixtral_12b,
        musicgen_medium,
        zamba2_7b,
        qwen3_moe_30b_a3b,
        moonshot_v1_16b_a3b,
        mamba2_130m,
        command_r_plus_104b,
        smollm_135m,
        qwen2_5_32b,
        llama3_405b,
    )
}

__all__ = ["ARCHS", "get_config", "for_shape"]


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def for_shape(cfg: ModelConfig, shape: ShapeConfig | str) -> ModelConfig:
    """Shape-specific config adjustments (documented in DESIGN.md §7)."""
    if isinstance(shape, str):
        shape = SHAPES[shape]
    over: dict = {}
    if shape.name == "long_500k" and cfg.family == "hybrid":
        # cap the shared attention span so the hybrid stays sub-quadratic
        over["sliding_window"] = 4096
    if shape.kind == "prefill":
        over["attn_chunk"] = 2048
        # perf iteration D (fused flash-attention prefill) stays OPT-IN:
        # attn_impl="flash" compiles under the full 512-device mesh, but the
        # CPU interpret-mode emulation re-fetches VMEM-resident K/V blocks
        # per grid step, so the HLO-derived memory term is not comparable on
        # this container (see EXPERIMENTS.md §Perf iteration D).
    if shape.kind == "decode" and cfg.n_heads and not _legacy():
        # perf iteration C2: int8 KV cache + integer score/PV dots for serving
        over["kv_quant_int8"] = True
    return dataclasses.replace(cfg, **over) if over else cfg


def _legacy() -> bool:
    import os

    return os.environ.get("REPRO_LEGACY_NORM", "0") == "1"
