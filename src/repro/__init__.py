"""repro: Memory-Immersed Collaborative Digitization for CiM deep learning,
as a production-grade multi-pod JAX framework."""

__version__ = "1.0.0"
