"""Cycle-pipelined multi-conversion schedules over fabric groups.

Extends ``core.schedule`` (one-shot Figs. 2/3 timelines) to steady-state
pipelines: conversions are issued back-to-back under explicit resource
reservation — an array is either computing, holding its analog MAV for
digitization, generating references, or comparing; the hybrid/flash reference
banks are serialized shared resources (a reference array cannot hold flash
references and run a SAR ref-gen ramp in the same cycle).

Physical constraints encoded:
  * the *computing* array holds V_MAV on its sum lines until its digitization
    completes — it cannot start the next MAV (the paper's halved per-array
    throughput in pair-SAR mode);
  * a flash compare needs the entire reference bank for that cycle;
  * conventional baselines get a sample-and-hold dedicated ADC, so the array
    computes the next MAV while the ADC converts the previous one (the
    strongest-possible baseline for the iso-area comparison).

The headline check lives in :func:`iso_area_comparison`: at equal chip area
the in-memory fabric's cheap digitizers (Table I) buy enough extra arrays to
beat the conventional-ADC fabric's conversions/cycle/mm^2 (pair_sar, hybrid),
reproducing the paper's throughput-recovery claim.
"""

from __future__ import annotations

from typing import List, Optional, Sequence

from repro.core.schedule import ScheduleResult, Slot, pair_sar_schedule
from repro.fabric.topology import FabricConfig

__all__ = [
    "pipelined_schedule",
    "fabric_throughput",
    "iso_area_comparison",
    "conversion_cycles",
    "overlap_rounds",
    "overlapped_mesh_latency",
    "link_validation",
]


def conversion_cycles(placement, rate_per_compute: float) -> float:
    """Cycles to drain one layer's conversions on its busiest compute array —
    the per-layer latency formula shared by ``fabric.report``'s rows and
    :func:`overlapped_mesh_latency` (one definition, so the overlap's serial
    baseline can never drift from the report's ``latency_s``)."""
    return placement.conversions_per_array_max / rate_per_compute


def _pair_sar(fabric: FabricConfig, n_conversions: int) -> ScheduleResult:
    # Fig. 2's role-swap timeline admits no extra pipelining — the computing
    # array holds V_MAV throughout its digitization — so the steady state IS
    # the core one-shot schedule, back to back; delegate rather than re-model.
    return pair_sar_schedule(bits=fabric.adc_bits, n_conversions=n_conversions)


def _flash(fabric: FabricConfig, n_conversions: int) -> ScheduleResult:
    nc = fabric.compute_arrays_per_group
    n_ref = fabric.n_ref_per_group
    slots: List[Slot] = []
    nf = [0] * nc
    bank_free = 0  # the whole reference bank serializes compare cycles
    end = 0
    for conv in range(n_conversions):
        i = conv % nc
        t = max(nf[i], bank_free - 1)
        slots.append(Slot(t, f"C{i}", "compute"))
        slots.append(Slot(t + 1, f"C{i}", "compare"))
        for r in range(n_ref):
            slots.append(Slot(t + 1, f"R{r}", "flash_ref"))
        nf[i] = t + 2
        bank_free = t + 2
        end = max(end, t + 2)
    return ScheduleResult(slots, end, n_conversions, nc + n_ref)


def _hybrid(fabric: FabricConfig, n_conversions: int) -> ScheduleResult:
    """Wave-pipelined Fig. 3: all compute arrays evaluate together, take
    staggered turns on the shared flash bank (one compare cycle each — a
    reference array cannot hold flash references while ramping a SAR
    ref-gen), then pair off with reference arrays for parallel SAR tails.
    Computing arrays hold V_MAV from compute until their SAR completes, so
    the next wave starts only after the tails drain."""
    bits, f = fabric.adc_bits, fabric.flash_bits
    nc = fabric.compute_arrays_per_group
    n_ref = fabric.n_ref_per_group
    sar = bits - f
    slots: List[Slot] = []
    t = 0
    done = 0
    while done < n_conversions:
        wave = min(nc, n_conversions - done)
        for i in range(wave):
            slots.append(Slot(t, f"C{i}", "compute"))
        for i in range(wave):  # staggered flash compares, one bank turn each
            slots.append(Slot(t + 1 + i, f"C{i}", "compare"))
            for j in range(n_ref):
                slots.append(Slot(t + 1 + i, f"R{j}", "flash_ref"))
        # SAR tails in parallel across distinct reference arrays; if the wave
        # outnumbers the bank, tails run in ceil(wave/n_ref) serial batches
        sar_start = t + 1 + wave
        batches = -(-wave // n_ref)
        for i in range(wave):
            b, r = divmod(i, n_ref)
            for c in range(sar_start + b * sar, sar_start + (b + 1) * sar):
                slots.append(Slot(c, f"C{i}", "hold"))
                slots.append(Slot(c, f"R{r}", "ref_gen"))
        t = sar_start + batches * sar
        done += wave
    return ScheduleResult(slots, t, n_conversions, nc + n_ref)


def _conventional(fabric: FabricConfig, n_conversions: int) -> ScheduleResult:
    """Dedicated sample-and-hold ADC: compute overlaps the previous
    conversion; throughput limited by max(1, ADC latency)."""
    lat = 1 if fabric.mode == "conventional_flash" else fabric.adc_bits
    slots: List[Slot] = []
    t = 0
    for conv in range(n_conversions):
        slots.append(Slot(t, "A0", "compute"))
        for c in range(t + 1, t + 1 + lat):
            slots.append(Slot(c, "A0", "adc"))  # off-array ADC busy, array free
        t += max(1, lat)
    end = (n_conversions - 1) * max(1, lat) + 1 + lat  # last ADC drain
    return ScheduleResult(slots, end, n_conversions, 1)


_SCHEDULERS = {
    "pair_sar": _pair_sar,
    "flash": _flash,
    "hybrid": _hybrid,
    "conventional_sar": _conventional,
    "conventional_flash": _conventional,
}


def pipelined_schedule(fabric: FabricConfig, n_conversions: int = 32) -> ScheduleResult:
    """Steady-state schedule of ``n_conversions`` on ONE digitization group.

    Example::

        >>> from repro.fabric import FabricConfig, pipelined_schedule
        >>> s = pipelined_schedule(FabricConfig(mode="pair_sar", adc_bits=5, n_arrays=2), 8)
        >>> s.n_conversions, s.n_cycles > 0
        (8, True)
    """
    return _SCHEDULERS[fabric.mode](fabric, n_conversions)


def fabric_throughput(fabric: FabricConfig, n_conversions: int = 96) -> dict:
    """Chip-level steady-state throughput and utilization.

    Example::

        >>> from repro.fabric import FabricConfig, fabric_throughput
        >>> tp = fabric_throughput(FabricConfig(mode="hybrid", n_arrays=60))
        >>> tp["n_groups"], tp["chip_conversions_per_cycle"] > 0
        (10, True)
    """
    sched = pipelined_schedule(fabric, n_conversions)
    group_rate = sched.n_conversions / sched.n_cycles
    n_groups = fabric.n_groups
    per_array = group_rate / fabric.group_size
    chip_rate = group_rate * n_groups
    return {
        "mode": fabric.mode,
        "n_arrays": fabric.resolved_n_arrays(),
        "n_groups": n_groups,
        "group_conversions_per_cycle": group_rate,
        "conversions_per_cycle_per_array": per_array,
        "chip_conversions_per_cycle": chip_rate,
        "chip_conversions_per_s": chip_rate * fabric.freq_hz,
        "compute_utilization": sched.utilization("compute"),
        "chip_area_um2": fabric.chip_area_um2(),
        "throughput_per_mm2": chip_rate / (fabric.chip_area_um2() / 1e6),
    }


def overlap_rounds(compute_s: Sequence[float], link_s: Sequence[float]) -> float:
    """Total latency of double-buffered mesh rounds: the cross-chip
    reduce-scatter of layer ``i`` runs on the links while layer ``i+1``'s
    conversions are already in flight on the arrays (the partial-sum buffer
    is double-buffered, so the arrays never wait for the links unless a
    reduce-scatter outlasts the next layer's conversion schedule).

    ``compute_s[i]`` is layer i's conversion time, ``link_s[i]`` its
    reduce-scatter link time; returns the pipelined end-to-end seconds:
    ``compute_0 + sum(max(compute_i, link_{i-1})) + link_last``.

    Example::

        >>> overlap_rounds([1.0, 1.0, 1.0], [0.5, 0.5, 0.5])  # links fully hidden
        3.5
        >>> overlap_rounds([1.0, 1.0], [2.0, 0.0])  # link outlasts next layer
        3.0
    """
    if len(compute_s) != len(link_s):
        raise ValueError("compute_s and link_s must align layer-for-layer")
    if not compute_s:
        return 0.0
    t = compute_s[0]
    for i in range(1, len(compute_s)):
        t += max(compute_s[i], link_s[i - 1])
    return t + link_s[-1]


def overlapped_mesh_latency(sharded: Sequence, n_conversions: int = 96) -> dict:
    """Mesh latency with layer ``i``'s reduce-scatter overlapping layer
    ``i+1``'s conversions (see :func:`overlap_rounds`), for a list of
    :class:`~repro.fabric.shard.ShardedPlacement` layers.

    Returns serial vs overlapped end-to-end seconds plus how much link time
    the overlap hides — the number ``sharded_fabric_report`` folds into its
    totals.

    Example::

        >>> from repro.fabric import ChipMeshConfig, FabricConfig, map_matmul, shard_placement
        >>> fb = FabricConfig(mode="pair_sar", n_arrays=8)
        >>> cm = ChipMeshConfig(model=2, fabric=fb)
        >>> sps = [shard_placement(map_matmul(f"l{i}", 4, 64, 64, fb), cm) for i in range(3)]
        >>> r = overlapped_mesh_latency(sps)
        >>> 0 < r["overlapped_latency_s"] <= r["serial_latency_s"]
        True
    """
    if not sharded:
        return {
            "serial_latency_s": 0.0,
            "overlapped_latency_s": 0.0,
            "hidden_link_s": 0.0,
            "link_hidden_fraction": 0.0,
        }
    fabric = sharded[0].chip_mesh.fabric
    tp = fabric_throughput(fabric, n_conversions)
    rate_per_compute = tp["group_conversions_per_cycle"] / fabric.compute_arrays_per_group
    compute = [
        conversion_cycles(sp.chip, rate_per_compute) / fabric.freq_hz for sp in sharded
    ]
    link = [sp.crosschip_latency_s for sp in sharded]
    serial = sum(compute) + sum(link)
    overlapped = overlap_rounds(compute, link)
    hidden = serial - overlapped
    total_link = sum(link)
    # hidden == sum(min(compute_i, link_{i-1})) lies in [0, total_link] by
    # construction; the clamp only guards float subtraction slop at the
    # link >= compute boundary (everything hidden) and the zero-link end
    fraction = min(1.0, max(0.0, hidden / total_link)) if total_link > 0 else 0.0
    return {
        "serial_latency_s": serial,
        "overlapped_latency_s": overlapped,
        "hidden_link_s": hidden,
        "link_hidden_fraction": fraction,
    }


def link_validation(
    sharded: Sequence, measured_collective_s: Optional[float], n_conversions: int = 96
) -> dict:
    """Measured-vs-modeled link latency for one forward pass — the
    validation loop the fused program closes.

    ``measured_collective_s`` is the fused program's collective wall time
    (``fabric.program.measure_forward``: fused minus collective-stripped,
    block-until-ready, host-simulation seconds); the modeled side is
    :func:`overlapped_mesh_latency`'s prediction in fabric seconds (10 MHz
    conversion clock, ``link_bits_per_s`` links). The two clock domains
    differ, so their ratio is a *clock-domain calibration constant* — the
    named ``link_clock_calibration`` key (``measured_over_modeled`` is kept
    as a backward-compatible alias), tracked for *stability across runs* by
    ``tools/ci_check.py`` (``BENCH_fabric_program.json`` /
    ``BENCH_fabric_graph.json``), never expected to be 1; ``None`` when the
    mesh has no links or nothing was measured. Both raw seconds are always
    reported next to it. When ``repro.obs`` metrics collection is active the
    three land on the ``fabric_modeled_link_seconds`` /
    ``fabric_measured_collective_seconds`` / ``fabric_link_clock_calibration``
    gauges.

    Example::

        >>> from repro.fabric import ChipMeshConfig, FabricConfig, map_matmul, shard_placement
        >>> fb = FabricConfig(mode="pair_sar", n_arrays=8)
        >>> cm = ChipMeshConfig(model=2, fabric=fb)
        >>> sps = [shard_placement(map_matmul(f"l{i}", 4, 64, 64, fb), cm) for i in range(2)]
        >>> v = link_validation(sps, measured_collective_s=1e-3)
        >>> v["modeled_link_s"] > 0 and v["link_clock_calibration"] > 0
        True
        >>> v["measured_over_modeled"] == v["link_clock_calibration"]
        True
    """
    from repro.obs import metrics as obs_metrics

    ov = overlapped_mesh_latency(sharded, n_conversions)
    modeled = sum(sp.crosschip_latency_s for sp in sharded)
    ratio = (
        measured_collective_s / modeled
        if measured_collective_s is not None and modeled > 0
        else None
    )
    obs_metrics.set_gauge(
        "fabric_modeled_link_seconds", modeled,
        help="Modeled reduce-scatter link time per forward pass (fabric clock).",
    )
    if measured_collective_s is not None:
        obs_metrics.set_gauge(
            "fabric_measured_collective_seconds", measured_collective_s,
            help="Measured fused-minus-local collective wall time (host clock).",
        )
    if ratio is not None:
        obs_metrics.set_gauge(
            "fabric_link_clock_calibration", ratio,
            help="Clock-domain calibration constant: measured host seconds / "
            "modeled fabric-clock link seconds.",
        )
    return {
        "modeled_link_s": modeled,
        "modeled_serial_latency_s": ov["serial_latency_s"],
        "modeled_overlapped_latency_s": ov["overlapped_latency_s"],
        "modeled_hidden_link_s": ov["hidden_link_s"],
        "modeled_link_hidden_fraction": ov["link_hidden_fraction"],
        "measured_collective_s": measured_collective_s,
        # the clock-domain calibration constant (host-simulation seconds over
        # modeled 10 MHz-fabric seconds); measured_over_modeled is the
        # backward-compatible alias older BENCH files used
        "link_clock_calibration": ratio,
        "measured_over_modeled": ratio,
    }


def iso_area_comparison(fabric: FabricConfig, n_conversions: int = 96) -> dict:
    """In-memory fabric vs the conventional-ADC fabric of equal chip area.

    The returned ``throughput_ratio`` >= 1 is the paper's recovery claim:
    cheap digitization buys more arrays than the collaborative duty-cycle
    loss costs (holds for pair_sar and hybrid against the dedicated-SAR
    baseline; one-to-many flash coupling trades throughput density for its
    ~51x ADC area and ~13x energy advantages).

    Example::

        >>> from repro.fabric import FabricConfig, iso_area_comparison
        >>> iso = iso_area_comparison(FabricConfig(mode="pair_sar", n_arrays=120))
        >>> iso["throughput_ratio"] >= 1.0 and iso["adc_area_ratio"] > 24
        True
    """
    conv = fabric.iso_area_counterpart()
    mine = fabric_throughput(fabric, n_conversions)
    theirs = fabric_throughput(conv, n_conversions)
    return {
        "in_memory": mine,
        "conventional": theirs,
        "adc_area_ratio": conv.digitizer_area_um2 / fabric.digitizer_area_um2,
        "array_count_ratio": mine["n_arrays"] / theirs["n_arrays"],
        "throughput_ratio": mine["chip_conversions_per_cycle"]
        / theirs["chip_conversions_per_cycle"],
    }
