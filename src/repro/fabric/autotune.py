"""Continuous batching for the fused graph program: bucketed program cache
+ cost-model-driven mesh/bucket autotuning.

The fused :class:`~repro.fabric.graph.GraphProgram` needs the runtime batch
to divide the mesh's data axis; a ragged request batch used to abandon it
for the ~115x-slower per-node loop (``BENCH_fabric_graph.json``) — exactly
the bursty mixed-length traffic the paper's "more arrays per footprint"
pitch targets. This module removes that cliff:

  * :class:`BucketedGraphCache` — a small LRU of compiled ``GraphProgram``s
    keyed by ``(padded batch, mesh, scan_layers, noisy)``. A ragged batch is
    zero-padded up to the nearest bucket boundary and served on the fused
    shard_map path with ``real_rows`` set: pad rows are masked out of every
    matmul node (they cannot perturb the global quantization scales), the
    logits are sliced back, and stats / metrics / link bits account only the
    real rows — so the padded run is **bit-exact** to the unpadded per-node
    reference and reports exactly like it. Requests that fit a bucket count
    a ``fabric_bucket_hits_total`` (NOT a ``ragged_batch`` fallback);
    only a batch larger than every bucket falls back, with the ``no_bucket``
    reason and a ``fabric_bucket_misses_total`` increment.
  * :func:`autotune_plan` — given a request-mix histogram
    (:func:`request_histogram`), search ``(data x model)`` mesh shapes and
    bucket boundary sets against the existing graph cost model
    (``overlapped_mesh_latency`` over ``shard_forward_graph`` placements,
    whose link term is the ``(C-1) * M * N * psum_bits`` reduce-scatter
    budget) under ``graph_eligibility``'s constraints (device count,
    ``K % (model * rows)``, GQA head groups ``n_heads % model == 0``), and
    return the cheapest feasible :class:`AutotunePlan`. The default mesh
    with a single max-batch bucket is always in the search space, so the
    plan's cost never exceeds the default's.

Bit-exactness rests on two properties built into the executors:

  1. **Per-row noise keys** — comparator draws derive from the GLOBAL row
     id (``fold_in(cmp_key, row_offset + i)`` inside
     ``core.cim_linear._bitplane_matmul``), so a row's draws are invariant
     to the batch size and the data split: pad rows never consume another
     row's noise.
  2. **Pad-row masking** — the fused program multiplies a ``(B, 1, 1)``
     {0, 1} mask into every matmul node output. A noisy ADC lifts a zero
     input row off zero (the half-LSB mav bias sits inside comparator
     sigma), which would otherwise leak into the global activation absmax
     at the next re-quantization boundary; the mask is a bitwise no-op on
     real rows.

Surfaced as ``serve --fabric-autotune`` and
``benchmarks/fabric_sweep.py --autotune-smoke`` (CI gate:
``BENCH_fabric_autotune.json``).
"""

from __future__ import annotations

import dataclasses
from collections import Counter, OrderedDict
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.cim_linear import CiMConfig
from repro.fabric.graph import (
    GraphProgram,
    compile_graph_forward,
    graph_eligibility,
    shard_forward_graph,
)
from repro.fabric.pipeline import overlapped_mesh_latency
from repro.fabric.topology import ChipMeshConfig, FabricConfig
from repro.obs import metrics as obs_metrics
from repro.obs.fallback import REASON_NO_BUCKET, record_fallback

__all__ = [
    "BucketedGraphCache",
    "AutotunePlan",
    "autotune_plan",
    "autotune_section",
    "request_histogram",
]


def request_histogram(batches: Iterable[int]) -> Dict[int, int]:
    """Collapse a request-batch trace into the ``{batch_size: count}``
    histogram :func:`autotune_plan` consumes.

    Example::

        >>> request_histogram([3, 1, 3, 4])
        {1: 1, 3: 2, 4: 1}
    """
    hist = Counter()
    for b in batches:
        b = int(b)
        if b < 1:
            raise ValueError(f"request batch sizes must be >= 1, got {b}")
        hist[b] += 1
    return dict(sorted(hist.items()))


def _validate_buckets(buckets: Sequence[int], data: int) -> Tuple[int, ...]:
    out = tuple(sorted(set(int(b) for b in buckets)))
    if not out:
        raise ValueError("need at least one bucket boundary")
    for b in out:
        if b < 1 or b % data:
            raise ValueError(
                f"bucket boundary {b} must be a positive multiple of the "
                f"data axis ({data})"
            )
    return out


class BucketedGraphCache:
    """LRU cache of compiled fused graph programs over batch buckets.

    ``buckets`` are padded-batch boundaries (each a multiple of the mesh's
    data axis, ascending). A request batch ``B`` is served by the smallest
    bucket ``>= B``: the input is zero-padded to the bucket, run through the
    bucket's fused ``GraphProgram`` with ``real_rows=B``, and sliced back —
    bit-exact to the unpadded per-node reference, noisy ADC included. At
    most ``capacity`` compiled programs stay resident; the least recently
    used is evicted (its XLA executable is dropped, recompiled on next use).

    Counters (``repro.obs``, when collecting):
      * ``fabric_bucket_hits_total`` — requests that fit a bucket (served
        fused; a RAGGED batch landing in a bucket is a hit, not a
        ``ragged_batch`` fallback),
      * ``fabric_bucket_misses_total`` — requests larger than every bucket
        (fall back to the per-node loop with the ``no_bucket`` reason),
      * ``fabric_pad_waste_rows_total`` — pad rows added by bucket rounding.

    Example::

        >>> from repro.fabric import ChipMeshConfig, FabricConfig  # doctest: +SKIP
        >>> cm = ChipMeshConfig(data=2, model=2, fabric=FabricConfig(mode="pair_sar"))  # doctest: +SKIP
        >>> cache = BucketedGraphCache(cfg, cm, cim, buckets=(4, 8))  # doctest: +SKIP
        >>> y = cache(x_b3, weights)          # padded to 4, sliced to 3  # doctest: +SKIP
    """

    def __init__(
        self,
        cfg: ModelConfig,
        chip_mesh: ChipMeshConfig,
        cim: CiMConfig,
        buckets: Sequence[int],
        seq: int = 1,
        capacity: int = 4,
        scan_layers: bool = False,
        block_only: bool = False,
        backend: str = "auto",
    ):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.cfg = cfg
        self.chip_mesh = chip_mesh
        self.cim = cim
        self.buckets = _validate_buckets(buckets, chip_mesh.data)
        self.seq = seq
        self.capacity = capacity
        self.scan_layers = scan_layers
        self.block_only = block_only
        self.backend = backend
        self._programs: "OrderedDict[tuple, GraphProgram]" = OrderedDict()
        # host-side mirrors of the obs counters, live even with metrics off
        self.hits = 0
        self.misses = 0
        self.pad_waste_rows = 0
        self.compiles = 0
        self.evictions = 0

    def bucket_for(self, batch: int) -> Optional[int]:
        """Smallest bucket boundary ``>= batch`` (None when none fits)."""
        for b in self.buckets:
            if b >= batch:
                return b
        return None

    def _key(self, padded_batch: int, noisy: bool) -> tuple:
        return (
            padded_batch,
            (self.chip_mesh.data, self.chip_mesh.model),
            self.scan_layers,
            noisy,
        )

    def program_for(self, padded_batch: int, noisy: bool = False) -> GraphProgram:
        """The compiled program serving bucket ``padded_batch`` — LRU get,
        compiling (and evicting the least recently used entry past
        ``capacity``) on first touch."""
        key = self._key(padded_batch, noisy)
        prog = self._programs.get(key)
        if prog is not None:
            self._programs.move_to_end(key)
            return prog
        prog = compile_graph_forward(
            self.cfg, self.chip_mesh, cim=self.cim, backend=self.backend,
            tokens=padded_batch * self.seq, block_only=self.block_only,
            scan_layers=self.scan_layers,
        )
        self.compiles += 1
        self._programs[key] = prog
        while len(self._programs) > self.capacity:
            self._programs.popitem(last=False)
            self.evictions += 1
        return prog

    def __call__(self, x, weights, key=None, return_stats: bool = False):
        """Serve one request batch ``x`` of shape ``(B, S, d)``.

        Fits a bucket: zero-pad to the boundary, run fused with
        ``real_rows=B``, slice back — results and stats are exactly the
        unpadded reference's. No bucket fits: ``no_bucket`` fallback to the
        per-node loop on the raw batch.
        """
        b = x.shape[0]
        pb = self.bucket_for(b)
        if pb is None:
            self.misses += 1
            record_fallback(
                "fabric.autotune", REASON_NO_BUCKET,
                f"batch {b} exceeds largest bucket {self.buckets[-1]}",
            )
            if obs_metrics.active():
                obs_metrics.inc(
                    "fabric_bucket_misses_total",
                    help="Requests larger than every configured batch bucket.",
                )
            prog = self.program_for(self.buckets[-1], noisy=key is not None)
            return prog.reference_forward(
                x, weights, key=key, return_stats=return_stats
            )
        self.hits += 1
        self.pad_waste_rows += pb - b
        if obs_metrics.active():
            obs_metrics.inc(
                "fabric_bucket_hits_total",
                help="Requests served via a bucketed fused graph program.",
            )
            if pb > b:
                obs_metrics.inc(
                    "fabric_pad_waste_rows_total", pb - b,
                    help="Zero-pad rows added by bucket rounding.",
                )
        prog = self.program_for(pb, noisy=key is not None)
        if pb > b:
            pad = jnp.zeros((pb - b,) + x.shape[1:], x.dtype)
            x = jnp.concatenate([x, pad], axis=0)
        return prog(
            x, weights, key=key, return_stats=return_stats,
            real_rows=b if pb > b else None,
        )

    def stats(self) -> dict:
        """Host-side counter snapshot (mirrors the obs counters)."""
        return {
            "hits": self.hits,
            "misses": self.misses,
            "pad_waste_rows": self.pad_waste_rows,
            "compiles": self.compiles,
            "evictions": self.evictions,
            "resident_programs": len(self._programs),
            "buckets": list(self.buckets),
        }


@dataclasses.dataclass(frozen=True)
class AutotunePlan:
    """One feasible point of the mesh x bucket search, cost-model priced.

    ``expected_latency_s`` is the request-mix-weighted overlapped mesh
    latency of one fused forward per request (each request priced at its
    bucket's padded batch); ``baseline_latency_s`` prices the same mix on
    the default mesh with one max-batch bucket (the cheapest feasible
    single-bucket plan when the default mesh is ineligible).
    ``speedup_vs_baseline`` >= 1 by construction — the baseline is in the
    search space."""

    data: int
    model: int
    buckets: Tuple[int, ...]
    expected_latency_s: float
    baseline_latency_s: float
    searched: int

    @property
    def mesh(self) -> Tuple[int, int]:
        return (self.data, self.model)

    @property
    def speedup_vs_baseline(self) -> float:
        if self.expected_latency_s <= 0:
            return 1.0
        return self.baseline_latency_s / self.expected_latency_s


def _bucket_candidates(hist: Mapping[int, int], data: int) -> List[Tuple[int, ...]]:
    """Candidate bucket boundary sets for a mesh with data axis ``data``:
    the exact-fit quantile set (every observed batch rounded up to the
    axis), power-of-two multiples of the axis, and the single max bucket —
    all padded-batch multiples of ``data`` by construction."""

    def up(b: int) -> int:
        return ((b + data - 1) // data) * data

    maxb = up(max(hist))
    exact = tuple(sorted({up(b) for b in hist}))
    pow2 = []
    m = 1
    while data * m < maxb:
        pow2.append(data * m)
        m *= 2
    pow2.append(maxb)
    cands = {exact, tuple(pow2), (maxb,)}
    return sorted(cands)


def autotune_plan(
    cfg: ModelConfig,
    hist: Mapping[int, int],
    n_chips: int,
    fabric: FabricConfig,
    seq: int = 1,
    cim: Optional[CiMConfig] = None,
    default_mesh: Optional[Tuple[int, int]] = None,
    max_buckets: int = 8,
) -> AutotunePlan:
    """Search mesh shapes x bucket boundaries for the cheapest feasible
    serving plan under the graph cost model.

    Candidate meshes are every ``(data, model)`` factorization of
    ``n_chips``; a mesh is feasible only when :func:`graph_eligibility`
    returns no problems for the model's sharded forward graph on it (this
    is what rejects e.g. GQA-violating model axes, ``n_heads % model``).
    Candidate bucket sets come from the histogram (exact-fit quantiles,
    power-of-two multiples of the data axis, single max bucket), capped at
    ``max_buckets`` boundaries. Cost of a plan = sum over the histogram of
    ``count * overlapped_latency_s`` of one fused forward at the request's
    padded-bucket batch, normalized per request.

    ``default_mesh`` (default ``(1, n_chips)``) with the single max bucket
    is always evaluated as the baseline; since it is also a search
    candidate, ``plan.expected_latency_s <= plan.baseline_latency_s``.

    Example::

        >>> from repro.fabric import FabricConfig  # doctest: +SKIP
        >>> plan = autotune_plan(cfg, {1: 5, 3: 10}, 4, FabricConfig(mode="pair_sar"))  # doctest: +SKIP
        >>> plan.mesh, plan.buckets  # doctest: +SKIP
        ((2, 2), (2, 4))
    """
    if not hist:
        raise ValueError("autotune_plan needs a non-empty request histogram")
    if n_chips < 1:
        raise ValueError(f"n_chips must be >= 1, got {n_chips}")
    if default_mesh is None:
        default_mesh = (1, n_chips)
    total = sum(hist.values())

    lat_cache: Dict[Tuple[int, int, int], float] = {}
    elig_cache: Dict[Tuple[int, int], bool] = {}

    def feasible(d: int, m: int) -> bool:
        if (d, m) not in elig_cache:
            cm = ChipMeshConfig(data=d, model=m, fabric=fabric)
            graph, placements = shard_forward_graph(
                cfg, cm, tokens=d * seq, cim=cim
            )
            elig_cache[(d, m)] = not graph_eligibility(graph, placements, cm)
        return elig_cache[(d, m)]

    def bucket_latency(d: int, m: int, pb: int) -> float:
        if (d, m, pb) not in lat_cache:
            cm = ChipMeshConfig(data=d, model=m, fabric=fabric)
            _, placements = shard_forward_graph(
                cfg, cm, tokens=pb * seq, cim=cim
            )
            lat = overlapped_mesh_latency(placements)
            lat_cache[(d, m, pb)] = lat["overlapped_latency_s"]
        return lat_cache[(d, m, pb)]

    def plan_cost(d: int, m: int, buckets: Tuple[int, ...]) -> float:
        cost = 0.0
        for b, count in hist.items():
            pb = next((bb for bb in buckets if bb >= b), None)
            if pb is None:  # pragma: no cover — candidate sets cover maxb
                return float("inf")
            cost += count * bucket_latency(d, m, pb)
        return cost / total

    meshes = [
        (d, n_chips // d) for d in range(1, n_chips + 1) if n_chips % d == 0
    ]
    searched = 0
    best: Optional[Tuple[float, int, Tuple[int, int], Tuple[int, ...]]] = None
    baseline_cost = float("inf")
    single_cost = float("inf")  # cheapest feasible single-max-bucket plan
    for d, m in meshes:
        if not feasible(d, m):
            continue
        for buckets in _bucket_candidates(hist, d):
            if len(buckets) > max_buckets:
                continue
            searched += 1
            cost = plan_cost(d, m, buckets)
            if len(buckets) == 1:
                single_cost = min(single_cost, cost)
                if (d, m) == tuple(default_mesh):
                    baseline_cost = min(baseline_cost, cost)
            # tie-break: fewer buckets (fewer compiles), then smaller data
            # axis (less padding exposure) — deterministic across runs
            cand = (cost, len(buckets), (d, m), buckets)
            if best is None or cand < best:
                best = cand
    if best is None:
        raise ValueError(
            f"no feasible (data x model) mesh for {cfg.name} on {n_chips} "
            f"chip(s) — graph_eligibility rejected every factorization"
        )
    cost, _, (d, m), buckets = best
    if baseline_cost == float("inf"):
        # default mesh is ineligible for this model — anchor the baseline at
        # the cheapest feasible un-bucketed (single max-batch) plan instead,
        # keeping plan cost <= baseline by construction
        baseline_cost = single_cost
    return AutotunePlan(
        data=d, model=m, buckets=buckets,
        expected_latency_s=cost, baseline_latency_s=baseline_cost,
        searched=searched,
    )


def autotune_section(
    plan: AutotunePlan, cache: Optional[BucketedGraphCache] = None
) -> dict:
    """The serve rollup's ``autotune`` report section: the chosen plan plus
    (when a cache is live) its bucket hit/miss/pad-waste counters —
    rendered by ``fabric.report.render_markdown`` alongside the mesh
    totals."""
    out = {
        "mesh": f"{plan.data}x{plan.model}",
        "buckets": list(plan.buckets),
        "expected_latency_s": plan.expected_latency_s,
        "baseline_latency_s": plan.baseline_latency_s,
        "speedup_vs_baseline": plan.speedup_vs_baseline,
        "searched": plan.searched,
    }
    if cache is not None:
        out["cache"] = cache.stats()
    return out
