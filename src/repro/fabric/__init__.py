"""Chip-level collaborative CiM fabric (paper Figs. 1-3, 5c, Table I).

The paper's headline claim is system-level: memory-immersed digitization
shrinks the per-array ADC ~25x (vs SAR) / ~51x (vs Flash), so many more CiM
arrays fit in the same chip footprint — recovering the halved per-array
throughput of collaborative digitization and cutting external memory
accesses because more weights stay resident. This package models that chip:

  * :mod:`repro.fabric.topology` — ``FabricConfig``: a grid of CiM arrays
    wired as one of the paper's networking configurations (``pair_sar`` /
    ``flash`` / ``hybrid``) or a conventional dedicated-ADC baseline; array
    counts can be derived from an area budget via ``core.energy_area``.
  * :mod:`repro.fabric.mapper` — tile an arbitrary matmul (or a whole
    ``ModelConfig``) onto the fabric: K split across arrays at ``rows``
    boundaries, N across array columns, M across time; yields a placement
    plus weight-load (external-memory-access) counts.
  * :mod:`repro.fabric.pipeline` — cycle-pipelined multi-conversion schedule
    over N arrays (role swapping, shared flash-bank arbitration) extending
    ``core.schedule``; chip throughput / utilization and the iso-area
    throughput-recovery comparison.
  * :mod:`repro.fabric.tiles` — THE per-(column-tile, K-shard) inner loop
    (``column_tile_matmul`` + analytic ``fake_quant`` stats) every executor
    shares; one definition is what keeps the single-chip, sequential-loop,
    shard_map, and fused whole-model paths bit-for-bit interchangeable.
  * :mod:`repro.fabric.execute` — batched numerical execution of a mapped
    placement through the ``core.cim_linear`` machinery; a mapped layer
    matches the unmapped op bit-for-bit (noiseless ADC).
  * :mod:`repro.fabric.report` — per-layer and end-to-end
    area / energy / latency / EMA rollups, rendered like
    ``roofline.report``.
  * :mod:`repro.fabric.shard` — shard mapped placements across a mesh of
    chips (``ChipMeshConfig``): K-parallel tiles over the ``model`` axis
    (digital partial sums combined with a reduce-scatter over inter-chip
    links), batch over ``data``; divisibility fallbacks follow
    ``launch.shardings``. Execution backends: a host-sequential chip loop
    or a real multi-device ``jax.experimental.shard_map`` SPMD program
    (``backend="auto"|"sequential"|"shard_map"``, ``resolve_backend``).
    ``sharded_fabric_report`` separates on-chip EMA from cross-chip link
    traffic and reports double-buffered round-overlap latency
    (``overlapped_mesh_latency``).
  * :mod:`repro.fabric.program` — compile a whole mapped model into ONE
    fused shard_map forward (``compile_forward`` -> ``FabricProgram``):
    layer i's reduce-scatter output stays sharded as layer i+1's input,
    one all-gather at the end, per-layer ``fold_in`` noise keys; bit-exact
    vs the per-layer ``execute_sharded_matmul`` loop on a 1x1 mesh.
    ``measure_forward`` wall-clocks the fused collectives and
    ``pipeline.link_validation`` reports them next to the modeled link
    latency.
  * :mod:`repro.fabric.autotune` — continuous batching: a bucketed LRU of
    compiled graph programs (``BucketedGraphCache``) that zero-pads ragged
    batches onto the fused path bit-exactly, plus a mesh/bucket autotuner
    (``autotune_plan``) that searches the graph cost model for the cheapest
    feasible serving plan given a request-mix histogram.

Paper-figure correspondence: Fig. 1 (networking configurations) ->
``FabricConfig.mode``; Fig. 2 (pair SAR role swap) -> ``pair_sar`` groups;
Fig. 3 + 5c (hybrid shared flash bank) -> ``hybrid`` groups and the
pipeline's bank arbitration; Table I anchors the area/energy rollups.

See ``docs/fabric.md`` for the full architecture guide.
"""

from repro.fabric.autotune import (
    AutotunePlan,
    BucketedGraphCache,
    autotune_plan,
    autotune_section,
    request_histogram,
)
from repro.fabric.execute import execute_linear, execute_matmul
from repro.fabric.graph import (
    GraphProgram,
    compile_graph_forward,
    graph_eligibility,
    per_node_forward,
    shard_forward_graph,
    stack_block_weights,
    transformer_graph_weights,
    unstack_block_weights,
)
from repro.fabric.mapper import (
    ForwardGraph,
    GraphNode,
    LayerPlacement,
    TileAssignment,
    map_matmul,
    map_model,
    model_block_template,
    model_forward_chain,
    model_forward_graph,
    model_matmuls,
)
from repro.fabric.pipeline import (
    conversion_cycles,
    fabric_throughput,
    iso_area_comparison,
    link_validation,
    overlap_rounds,
    overlapped_mesh_latency,
    pipelined_schedule,
)
from repro.fabric.program import (
    FabricProgram,
    compile_forward,
    measure_forward,
    per_layer_forward,
    program_eligibility,
)
from repro.fabric.report import (
    fabric_report,
    graph_section,
    render_markdown,
    sharded_fabric_report,
)
from repro.fabric.shard import (
    ShardedPlacement,
    execute_sharded_matmul,
    resolve_backend,
    shard_model,
    shard_placement,
)
from repro.fabric.tiles import analytic_cim_stats, column_tile_matmul
from repro.fabric.topology import (
    BITCELL_UM2_65NM,
    MODES,
    ChipMeshConfig,
    FabricConfig,
    arrays_for_area,
)

__all__ = [
    "FabricConfig",
    "ChipMeshConfig",
    "MODES",
    "BITCELL_UM2_65NM",
    "arrays_for_area",
    "TileAssignment",
    "LayerPlacement",
    "map_matmul",
    "map_model",
    "model_matmuls",
    "model_forward_chain",
    "GraphNode",
    "ForwardGraph",
    "model_forward_graph",
    "model_block_template",
    "conversion_cycles",
    "fabric_throughput",
    "iso_area_comparison",
    "overlap_rounds",
    "overlapped_mesh_latency",
    "link_validation",
    "pipelined_schedule",
    "column_tile_matmul",
    "analytic_cim_stats",
    "execute_matmul",
    "execute_linear",
    "ShardedPlacement",
    "shard_placement",
    "shard_model",
    "resolve_backend",
    "execute_sharded_matmul",
    "FabricProgram",
    "compile_forward",
    "per_layer_forward",
    "measure_forward",
    "program_eligibility",
    "GraphProgram",
    "compile_graph_forward",
    "per_node_forward",
    "graph_eligibility",
    "shard_forward_graph",
    "transformer_graph_weights",
    "stack_block_weights",
    "unstack_block_weights",
    "fabric_report",
    "sharded_fabric_report",
    "graph_section",
    "render_markdown",
    "BucketedGraphCache",
    "AutotunePlan",
    "autotune_plan",
    "autotune_section",
    "request_histogram",
]
