"""The shared per-(column-tile, K-shard) inner loop of every fabric executor.

``fabric.execute`` (single chip), ``fabric.shard`` (both the sequential chip
loop and the shard_map SPMD program), ``fabric.program`` (the whole-model
fused chain forward), and ``fabric.graph`` (the full-transformer-block fused
graph) all execute the same physical operation per chip: walk the
output-column tiles of a quantized ``(M, K) @ (K, N)`` block, run each tile
through ``core.cim_linear``'s per-plane machinery with a per-tile
``fold_in(key, nt)`` noise key, and accumulate conversion/comparison stats.

Before this module each path carried its own copy of that loop, and the
bit-exactness guarantees between them rested on the copies never drifting.
Now there is ONE definition — :func:`column_tile_matmul` — and the
equivalence tests pin the callers to it.

Stats are meaningful in BOTH fidelity modes: ``bitplane`` counts the actual
ADC conversions / comparator firings performed by ``_bitplane_matmul``;
``fake_quant`` (a vectorized surrogate with no explicit per-plane loop)
counts them analytically via :func:`analytic_cim_stats` — the same
``planes x M x k-tiles x N`` formula as ``LayerPlacement.conversions`` and
``core.cim_linear.digitization_stats``.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.cim_linear import (
    CimStats,
    CiMConfig,
    _bitplane_matmul,
    _fake_quant_matmul,
)

__all__ = ["column_tile_matmul", "analytic_cim_stats"]


def analytic_cim_stats(cim: CiMConfig, m: int, k_tiles: int, n: int) -> CimStats:
    """Analytic digitization stats for one executed ``(m, k_tiles*rows, n)``
    block: every (input-plane x weight-plane) pair of every
    (row, k-tile, output-column) triple is one conversion; expected
    comparator firings follow the configured search tree under the Binomial
    MAV model (``core.search_tree`` / ``core.mav_stats``) — exactly
    ``digitization_stats``'s accounting, shaped as a :class:`CimStats`.

    Example::

        >>> from repro.core.cim_linear import CiMConfig
        >>> cim = CiMConfig(mode="fake_quant", a_bits=4, w_bits=4, adc_bits=5, rows=16)
        >>> st = analytic_cim_stats(cim, m=2, k_tiles=3, n=8)
        >>> int(st.conversions), int(st.comparisons) > 0
        (768, True)
    """
    from repro.core.mav_stats import analytic_code_pmf

    conversions = cim.a_bits * cim.w_bits * m * k_tiles * n
    pmf = analytic_code_pmf(cim.rows, cim.adc_bits)
    e_cmp = cim.search_tree().expected_depth(pmf)
    return CimStats(
        conversions=jnp.asarray(conversions, jnp.int32),
        comparisons=jnp.asarray(round(conversions * float(e_cmp)), jnp.int32),
    )


def column_tile_matmul(
    x_int: jnp.ndarray,
    w_int: jnp.ndarray,
    cim: CiMConfig,
    cols: int,
    key: Optional[jax.Array] = None,
    row_offset=0,
) -> Tuple[jnp.ndarray, CimStats]:
    """Execute one chip's quantized block tile-by-tile over its output columns.

    ``x_int``: (M, K) integer-valued activations; ``w_int``: (K, N)
    integer-valued weights (this chip's K-shard). Output-column tile ``nt``
    covers columns ``[nt*cols, (nt+1)*cols)`` and draws its ADC noise from
    ``fold_in(key, nt)`` then per-row ``fold_in(·, row_offset + i)`` inside
    ``_bitplane_matmul`` — the derivation every fabric executor shares, which
    is what keeps the single-chip, sequential-chip-loop, shard_map, and fused
    whole-model paths bit-for-bit interchangeable. ``row_offset`` is the
    global index of ``x_int``'s first row (data-shard callers pass their
    shard's start row), making each row's draws invariant to the batch shape
    and the data split — the contract ``fabric.autotune``'s zero-padded
    bucketed batches rely on.

    Returns the UNSCALED integer-valued result ``(M, N)`` plus
    :class:`CimStats` (actual counts in ``bitplane`` mode, analytic in
    ``fake_quant`` — multiplying by the caller's ``sx * sw`` afterwards is
    bit-identical to scaling each tile before concatenation, since the
    per-column scales broadcast tile-locally).

    Example::

        >>> import jax, jax.numpy as jnp
        >>> from repro.core.cim_linear import CiMConfig, quantize_symmetric
        >>> cim = CiMConfig(mode="bitplane", a_bits=4, w_bits=4, adc_bits=5, rows=16, ste=False)
        >>> x_int, _ = quantize_symmetric(jax.random.normal(jax.random.PRNGKey(0), (2, 32)), 4, True)
        >>> w_int, _ = quantize_symmetric(jax.random.normal(jax.random.PRNGKey(1), (32, 48)), 4, True, per_axis=-1)
        >>> y, st = column_tile_matmul(x_int, w_int, cim, cols=32)
        >>> y.shape, int(st.conversions)
        ((2, 48), 3072)
    """
    n = w_int.shape[1]
    if cim.mode != "bitplane":
        # the fake_quant surrogate is column-independent (its quantizer step
        # is config-only), so one full-width call is bit-identical to the
        # per-tile walk and keeps the traced graph n_tiles-times smaller
        y, _ = _fake_quant_matmul(x_int, w_int, cim)
        k_tiles = math.ceil(x_int.shape[1] / cim.rows)
        st = analytic_cim_stats(cim, x_int.shape[0], k_tiles, n)
        return y, st
    n_tiles = math.ceil(n / cols)
    parts = []
    conversions = jnp.zeros((), jnp.int32)
    comparisons = jnp.zeros((), jnp.int32)
    for nt in range(n_tiles):
        n0, n1 = nt * cols, min((nt + 1) * cols, n)
        tkey = jax.random.fold_in(key, nt) if key is not None else None
        y_t, st = _bitplane_matmul(x_int, w_int[:, n0:n1], cim, tkey, row_offset)
        conversions = conversions + st.conversions
        comparisons = comparisons + st.comparisons
        parts.append(y_t)
    y = parts[0] if n_tiles == 1 else jnp.concatenate(parts, axis=1)
    return y, CimStats(conversions, comparisons)
