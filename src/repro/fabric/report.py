"""Per-layer and end-to-end fabric rollups (area / energy / latency / EMA).

Rendered alongside ``roofline.report``'s tables: one row per mapped layer,
then chip-level totals and the paper's headline chip-level ratios —
digitization area vs the dedicated 40 nm SAR (~25x) and Flash (~51x) ADCs
(Table I), and the iso-area throughput comparison against a conventional-ADC
fabric of equal footprint.

Multi-chip meshes (``fabric.shard``) roll up through
:func:`sharded_fabric_report`, which keeps the single-chip columns for the
per-chip shard every chip runs and adds the mesh's one new cost: cross-chip
reduce-scatter traffic (bits / energy / link latency), reported separately
from on-chip EMA so the report shows what sharding buys (residency, lower
on-chip EMA) against what it costs (link traffic).

  PYTHONPATH=src python -m repro.fabric.report --arch smollm-135m --mode hybrid
"""

from __future__ import annotations

import argparse
import json
from typing import List, Optional

from repro.core.energy_area import area_um2, energy_pj
from repro.fabric.mapper import LayerPlacement
from repro.fabric.pipeline import (
    conversion_cycles,
    fabric_throughput,
    iso_area_comparison,
    overlapped_mesh_latency,
)
from repro.fabric.topology import EMA_PJ_PER_BIT, ChipMeshConfig, FabricConfig

__all__ = ["fabric_report", "sharded_fabric_report", "graph_section", "render_markdown"]


def graph_section(graph, model_axis: int, program=None) -> dict:
    """The report's ``graph`` section for a ``ForwardGraph``: node-op
    census, the sibling branches the chain rollup undercounted, and the
    documented collective budget. ONE schema, shared by
    ``sharded_fabric_report(..., graph=...)`` and the serve rollup.

    ``program`` (a ``fabric.graph.GraphProgram``) attaches a ``scan``
    subsection when it was compiled with ``scan_layers=True``: the scan
    trip count, the per-block collective census and the out-of-scan tail's
    budget — ``census × n_blocks + tail`` sums to the section's
    ``collective_budget`` (the link traffic is identical; only trace and
    compile cost change).

    Example::

        >>> from repro.configs.registry import get_config
        >>> from repro.fabric import graph_section, model_forward_graph
        >>> g = model_forward_graph(get_config("smollm-135m"), 4, block_only=True)
        >>> sec = graph_section(g, 2)
        >>> sec["n_matmuls"], sec["collective_budget"]["all_gather"]
        (7, 1)
    """
    ops: dict = {}
    for nd in graph.nodes:
        ops[nd.op] = ops.get(nd.op, 0) + 1
    sec = {
        "n_nodes": len(graph.nodes),
        "ops": ops,
        "n_matmuls": len(graph.matmul_nodes),
        "siblings": graph.sibling_names(),
        "collective_budget": graph.collective_budget(model_axis),
    }
    if program is not None and getattr(program, "scan_layers", False):
        sec["scan"] = {
            "n_blocks": program.n_blocks,
            "block_census": program.block_graph.block_census(model_axis),
            "tail_budget": program.tail_graph.collective_budget(model_axis),
        }
    return sec


def _layer_row(
    p: LayerPlacement,
    fabric: FabricConfig,
    rate_per_compute: float,
    model_resident: bool,
) -> dict:
    cycles = conversion_cycles(p, rate_per_compute)
    e_conv = energy_pj(
        fabric.adc_style,
        fabric.adc_bits,
        vdd=fabric.vdd,
        flash_bits=fabric.flash_bits,
        flash_share=fabric.n_cim_per_group,
    )
    # steady-state EMA per forward pass: activations always stream; weights
    # re-fetch unless the WHOLE model stays resident — a layer that fits by
    # itself is still evicted when later layers overwrite its arrays
    ema_bits = p.activation_bits + (0 if model_resident else p.weight_load_bits)
    return {
        **p.stats(),
        "latency_cycles": cycles,
        "latency_s": cycles / fabric.freq_hz,
        "digitization_energy_pj": p.conversions * e_conv,
        "ema_bits_per_pass": ema_bits,
        "ema_energy_pj": ema_bits * EMA_PJ_PER_BIT,
    }


def _chip_sections(fabric: FabricConfig, tp: dict, n_conversions: int) -> dict:
    """Placement-independent report sections: chip + paper ratios + iso-area."""
    sections = {
        "chip": {
            "mode": fabric.mode,
            "n_arrays": fabric.resolved_n_arrays(),
            "n_compute_arrays": fabric.n_compute_arrays,
            "chip_area_mm2": fabric.chip_area_um2() / 1e6,
            "chip_adc_area_mm2": fabric.chip_adc_area_um2() / 1e6,
            "weight_capacity_bits": fabric.weight_capacity_bits(),
            **tp,
        }
    }
    if not fabric.mode.startswith("conventional"):
        n_arr = fabric.resolved_n_arrays()
        sections["paper_ratios"] = {
            # chip-level digitization-area ratios vs dedicated 40nm ADCs
            "adc_area_ratio_vs_sar": (n_arr * area_um2("sar", fabric.adc_bits))
            / fabric.chip_adc_area_um2(),
            "adc_area_ratio_vs_flash": (n_arr * area_um2("flash", fabric.adc_bits))
            / fabric.chip_adc_area_um2(),
        }
        sections["iso_area"] = iso_area_comparison(fabric, n_conversions)
    return sections


def fabric_report(
    placements: List[LayerPlacement],
    fabric: FabricConfig,
    n_conversions: int = 96,
) -> dict:
    """Roll a list of layer placements up into the chip-level report.

    Example::

        >>> from repro.fabric import FabricConfig, fabric_report, map_matmul
        >>> fb = FabricConfig(mode="hybrid", n_arrays=60)
        >>> rep = fabric_report([map_matmul("l", 1, 64, 64, fb)], fb)
        >>> sorted(rep)
        ['chip', 'iso_area', 'layers', 'paper_ratios', 'totals']
    """
    tp = fabric_throughput(fabric, n_conversions)
    rate_per_compute = (
        tp["group_conversions_per_cycle"] / fabric.compute_arrays_per_group
    )
    total_tiles = sum(p.n_weight_tiles for p in placements)
    model_resident = total_tiles <= fabric.n_compute_arrays
    layers = [
        _layer_row(p, fabric, rate_per_compute, model_resident) for p in placements
    ]
    totals = {
        "tiles": total_tiles,
        "model_resident": model_resident,
        "conversions": sum(r["conversions"] for r in layers),
        "latency_cycles": sum(r["latency_cycles"] for r in layers),
        "latency_s": sum(r["latency_s"] for r in layers),
        "digitization_energy_pj": sum(r["digitization_energy_pj"] for r in layers),
        "ema_bits_per_pass": sum(r["ema_bits_per_pass"] for r in layers),
        "ema_energy_pj": sum(r["ema_energy_pj"] for r in layers),
        "weight_program_bits": sum(r["weight_load_bits"] for r in layers),
    }
    return {
        **_chip_sections(fabric, tp, n_conversions),
        "layers": layers,
        "totals": totals,
    }


def sharded_fabric_report(
    sharded: list,
    chip_mesh: ChipMeshConfig,
    n_conversions: int = 96,
    measured: Optional[dict] = None,
    graph=None,
    program=None,
) -> dict:
    """Mesh-level rollup of :class:`~repro.fabric.shard.ShardedPlacement`s.

    Layer rows keep the single-chip columns — ``conversions``, digitization
    energy, and on-chip ``ema_bits_per_pass`` are mesh totals (summed over
    active chips); ``latency_cycles`` is the per-chip critical path (chips
    run in parallel) — and add the mesh's new cost columns:
    ``crosschip_bits_per_pass`` (ring reduce-scatter traffic combining the
    K-parallel partial sums), its link energy, and its link latency.
    Residency is per chip: each model-axis chip only has to hold its own
    K-shard, which is how a mesh turns a reload-bound model resident.

    ``measured`` (a ``fabric.program.measure_forward`` dict) attaches the
    fused program's measured-vs-modeled link-latency validation as a
    ``program_validation`` section, rendered next to the overlap totals.

    ``graph`` (a ``fabric.mapper.ForwardGraph`` whose matmul nodes produced
    ``sharded``) attaches a ``graph`` section — node taxonomy, the sibling
    branches the old chain rollup undercounted, and the documented
    collective budget. Passing the graph's placements here is what makes
    the totals include the k/v/up/router siblings' conversions, EMA, and
    link traffic. ``program`` additionally threads a scanned
    ``GraphProgram``'s per-block census into the section
    (:func:`graph_section`); the budget totals are identical scan or
    unroll — the scan changes compile cost, not link traffic.

    Example::

        >>> from repro.configs.registry import get_config
        >>> from repro.fabric import ChipMeshConfig, FabricConfig, shard_model, sharded_fabric_report
        >>> cm = ChipMeshConfig(model=4, fabric=FabricConfig(mode="hybrid", n_arrays=60))
        >>> sps = shard_model(get_config("smollm-135m"), cm, tokens=4, block_only=True)
        >>> rep = sharded_fabric_report(sps, cm)
        >>> rep["mesh"]["n_chips"], rep["totals"]["crosschip_bits_per_pass"] > 0
        (4, True)
    """
    fabric = chip_mesh.fabric
    tp = fabric_throughput(fabric, n_conversions)
    rate_per_compute = (
        tp["group_conversions_per_cycle"] / fabric.compute_arrays_per_group
    )
    # residency is per chip: every chip must hold its shard of EVERY layer
    chip_tiles = sum(sp.chip.n_weight_tiles for sp in sharded)
    mesh_resident = chip_tiles <= fabric.n_compute_arrays

    layers = []
    for sp in sharded:
        base = _layer_row(sp.chip, fabric, rate_per_compute, mesh_resident)
        active = sp.n_chips_active
        layers.append(
            {
                **base,
                "layer": sp.name,
                "m": sp.m,
                "k": sp.k,
                "n": sp.n,
                "k_splits": sp.k_splits,
                "d_splits": sp.d_splits,
                "chips_active": active,
                # mesh totals (chips run the same shard cost in parallel)
                "conversions": base["conversions"] * active,
                "digitization_energy_pj": base["digitization_energy_pj"] * active,
                "weight_load_bits": base["weight_load_bits"] * active,
                "ema_bits_per_pass": base["ema_bits_per_pass"] * active,
                "ema_energy_pj": base["ema_energy_pj"] * active,
                "crosschip_bits_per_pass": sp.crosschip_bits_per_pass,
                "crosschip_energy_pj": sp.crosschip_energy_pj,
                "crosschip_latency_s": sp.crosschip_latency_s,
                "latency_total_s": base["latency_s"] + sp.crosschip_latency_s,
            }
        )
    totals = {
        "tiles_per_chip": chip_tiles,
        "model_resident": mesh_resident,
        "conversions": sum(r["conversions"] for r in layers),
        "latency_cycles": sum(r["latency_cycles"] for r in layers),
        "latency_s": sum(r["latency_total_s"] for r in layers),
        "digitization_energy_pj": sum(r["digitization_energy_pj"] for r in layers),
        "ema_bits_per_pass": sum(r["ema_bits_per_pass"] for r in layers),
        "ema_energy_pj": sum(r["ema_energy_pj"] for r in layers),
        "weight_program_bits": sum(r["weight_load_bits"] for r in layers),
        "crosschip_bits_per_pass": sum(r["crosschip_bits_per_pass"] for r in layers),
        "crosschip_energy_pj": sum(r["crosschip_energy_pj"] for r in layers),
        "crosschip_latency_s": sum(r["crosschip_latency_s"] for r in layers),
    }
    # double-buffered rounds: layer i's reduce-scatter overlaps layer i+1's
    # conversion schedule (fabric.pipeline.overlap_rounds)
    overlap = overlapped_mesh_latency(sharded, n_conversions)
    totals["latency_s_overlapped"] = overlap["overlapped_latency_s"]
    totals["crosschip_latency_hidden_s"] = overlap["hidden_link_s"]
    totals["link_hidden_fraction"] = overlap["link_hidden_fraction"]
    report = {
        "mesh": {
            "shape": {"data": chip_mesh.data, "model": chip_mesh.model},
            "n_chips": chip_mesh.n_chips,
            "total_area_mm2": chip_mesh.total_area_um2() / 1e6,
            "total_weight_capacity_bits": chip_mesh.total_weight_capacity_bits(),
            "link_bits_per_s": chip_mesh.link_bits_per_s,
            "link_pj_per_bit": chip_mesh.link_pj_per_bit,
            "psum_bits": chip_mesh.psum_bits,
            "fallbacks": [f for sp in sharded for f in sp.fallbacks],
        },
        **_chip_sections(fabric, tp, n_conversions),
        "layers": layers,
        "totals": totals,
    }
    if measured is not None:
        report["program_validation"] = measured
    if graph is not None:
        report["graph"] = graph_section(graph, chip_mesh.model, program=program)
    return report


def render_markdown(report: dict, max_layers: Optional[int] = 24) -> str:
    """Markdown tables in the roofline.report house style.

    Handles both single-chip (``fabric_report``) and mesh
    (``sharded_fabric_report``) reports; mesh reports gain a header line and
    split / cross-chip-traffic columns.

    Example::

        >>> from repro.fabric import FabricConfig, fabric_report, map_matmul, render_markdown
        >>> fb = FabricConfig(mode="hybrid", n_arrays=60)
        >>> md = render_markdown(fabric_report([map_matmul("l", 1, 64, 64, fb)], fb))
        >>> md.splitlines()[0].startswith("### fabric: hybrid — 60 arrays")
        True
    """
    mesh = report.get("mesh")
    chip = report["chip"]
    out = [
        f"### fabric: {chip['mode']} — {chip['n_arrays']} arrays "
        f"({chip['n_compute_arrays']} compute), {chip['chip_area_mm2']:.3f} mm^2 "
        f"(ADC {chip['chip_adc_area_mm2']:.4f} mm^2), "
        f"{chip['chip_conversions_per_s']:.3g} conv/s"
        + (" per chip" if mesh else ""),
    ]
    if mesh:
        out.append(
            f"**mesh:** {mesh['shape']['data']}x{mesh['shape']['model']} "
            f"(data x model) = {mesh['n_chips']} chips, "
            f"{mesh['total_area_mm2']:.3f} mm^2 total, links "
            f"{mesh['link_bits_per_s']/1e9:.3g} Gbit/s @ "
            f"{mesh['link_pj_per_bit']:.3g} pJ/bit"
            + (f", {len(mesh['fallbacks'])} sharding fallback(s)"
               if mesh["fallbacks"] else "")
        )
    xcol = " KxD split | xchip/pass (bits) |" if mesh else ""
    out += [
        "",
        "| layer | MxKxN | tiles | rounds | resident | conv | lat (cyc) | "
        f"E_dig (pJ) | EMA/pass (bits) |{xcol}",
        "|---|---|---|---|---|---|---|---|---|" + ("---|---|" if mesh else ""),
    ]
    layers = report["layers"]
    shown = layers if max_layers is None else layers[:max_layers]
    for r in shown:
        xcell = (
            f" {r['k_splits']}x{r['d_splits']} | {r['crosschip_bits_per_pass']:.3g} |"
            if mesh
            else ""
        )
        out.append(
            f"| {r['layer']} | {r['m']}x{r['k']}x{r['n']} | {r['tiles']} | "
            f"{r['rounds']} | {'y' if r['resident'] else 'n'} | {r['conversions']:.3g} | "
            f"{r['latency_cycles']:.3g} | {r['digitization_energy_pj']:.3g} | "
            f"{r['ema_bits_per_pass']:.3g} |" + xcell
        )
    if max_layers is not None and len(layers) > max_layers:
        out.append(
            f"| ... {len(layers) - max_layers} more layers ... | | | | | | | | |"
            + (" | |" if mesh else "")
        )
    t = report["totals"]
    tiles_key = "tiles_per_chip" if mesh else "tiles"
    out += [
        "",
        f"**totals:** {t[tiles_key]} tiles{' per chip' if mesh else ''} "
        f"({'model-resident' if t['model_resident'] else 'rounds needed'}), "
        f"{t['conversions']:.3g} conversions, {t['latency_s']*1e3:.3g} ms, "
        f"{t['digitization_energy_pj']/1e6:.3g} uJ digitization, "
        f"{t['ema_energy_pj']/1e6:.3g} uJ on-chip external-memory"
        + (
            f", {t['crosschip_bits_per_pass']:.3g} bits / "
            f"{t['crosschip_energy_pj']/1e6:.3g} uJ cross-chip reduce-scatter"
            + (
                f", {t['latency_s_overlapped']*1e3:.3g} ms with double-buffered "
                f"round overlap ({t.get('link_hidden_fraction', 0.0)*100:.0f}% of "
                f"link time hidden)"
                if "latency_s_overlapped" in t
                else ""
            )
            if mesh
            else ""
        ),
    ]
    if "graph" in report:
        g = report["graph"]
        ops = ", ".join(f"{v} {k}" for k, v in sorted(g["ops"].items()))
        budget = g["collective_budget"]
        kinds = sorted({s.split(".")[-1] for s in g["siblings"]})
        out += [
            "",
            f"**forward graph:** {g['n_nodes']} nodes ({ops}); "
            f"{len(g['siblings'])} sibling branch(es)"
            + (f" ({'/'.join(kinds)})" if kinds else "")
            + " costed — the chain rollup skipped them; collective budget "
            f"{budget['reduce_scatter']} reduce-scatter + "
            f"{budget['all_gather']} all-gather, {budget['pmax']} "
            f"re-quantization boundaries"
            + (
                f"; scanned: block traced once, {g['scan']['n_blocks']} "
                "lax.scan iterations (census × n_blocks + tail == budget)"
                if "scan" in g
                else ""
            ),
        ]
    if "program_validation" in report:
        pv = report["program_validation"]
        ratio = pv.get("measured_over_modeled")
        meas = pv.get("measured_collective_s")
        line = (
            f"**fused program** ({pv.get('n_layers', '?')} layers, "
            f"{pv.get('backend', '?')}): "
        )
        if pv.get("fused_s") is not None:
            line += (
                f"forward {pv['fused_s']*1e3:.3g} ms wall vs per-layer loop "
                f"{pv['per_layer_s']*1e3:.3g} ms "
                f"({pv.get('fused_speedup_vs_per_layer', 0.0):.2f}x); "
            )
        line += (
            f"collectives measured "
            f"{'n/a' if meas is None else f'{meas*1e3:.3g} ms wall'} vs modeled "
            f"link {pv.get('modeled_link_s', 0.0)*1e3:.3g} ms fabric-time"
            + (f" (calibration ratio {ratio:.3g})" if ratio is not None else "")
        )
        out += ["", line]
    if "autotune" in report:
        at = report["autotune"]
        line = (
            f"**autotune:** mesh {at['mesh']}, buckets "
            f"{'/'.join(str(b) for b in at['buckets'])}; expected "
            f"{at['expected_latency_s']*1e3:.3g} ms/request vs baseline "
            f"{at['baseline_latency_s']*1e3:.3g} ms "
            f"({at['speedup_vs_baseline']:.2f}x, {at['searched']} plans searched)"
        )
        cachest = at.get("cache")
        if cachest:
            line += (
                f"; cache {cachest['hits']} hit(s) / {cachest['misses']} "
                f"miss(es), {cachest['pad_waste_rows']} pad row(s), "
                f"{cachest['compiles']} compile(s)"
            )
        out += ["", line]
    if "paper_ratios" in report:
        pr = report["paper_ratios"]
        iso = report["iso_area"]
        out += [
            "",
            f"**paper ratios (chip level):** ADC area vs dedicated SAR "
            f"{pr['adc_area_ratio_vs_sar']:.1f}x, vs dedicated Flash "
            f"{pr['adc_area_ratio_vs_flash']:.1f}x (paper: ~25x / ~51x)",
            f"**iso-area vs {iso['conventional']['mode']}:** "
            f"{iso['array_count_ratio']:.2f}x arrays, "
            f"{iso['throughput_ratio']:.2f}x chip throughput "
            f"({iso['in_memory']['chip_conversions_per_cycle']:.2f} vs "
            f"{iso['conventional']['chip_conversions_per_cycle']:.2f} conv/cycle)",
        ]
    return "\n".join(out)


def main():
    from repro.configs.registry import get_config
    from repro.fabric.mapper import map_model

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--mode", default="hybrid", choices=("pair_sar", "flash", "hybrid"))
    ap.add_argument("--arrays", type=int, default=256)
    ap.add_argument("--tokens", type=int, default=1)
    ap.add_argument("--block-only", action="store_true")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()

    fabric = FabricConfig(mode=args.mode, n_arrays=args.arrays)
    placements = map_model(
        get_config(args.arch), fabric, tokens=args.tokens, block_only=args.block_only
    )
    report = fabric_report(placements, fabric)
    if args.json:
        print(json.dumps(report, indent=2, default=float))
    else:
        print(render_markdown(report))


if __name__ == "__main__":
    main()
