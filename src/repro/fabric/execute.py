"""Numerically execute a mapped placement — batched, tile by tile.

The mapped path must be *bit-for-bit* the unmapped op: quantization scales
are computed once at the fabric level (per-tensor activations, per-column
weights — exactly ``core.cim_linear.cim_matmul``'s front-end), then every
output-column tile runs through the same per-plane machinery:

  * ``bitplane``   — ``core.cim_linear`` faithful per-plane path per tile
                     (noiseless memory-immersed ADC -> exact integer matmul
                     whenever ``2^adc_bits >= 2*rows``, as on the test chip);
  * ``fake_quant`` — the fused Pallas kernel (``kernels.ops.cim_matmul_op``)
                     per tile, interpret-mode on CPU.

K-tiling at ``rows`` boundaries happens *inside* the per-tile op and lands on
the same reduction slices the placement assigns to individual arrays, so the
per-array partial sums are the ones actually accumulated. Exact equality with
the unmapped op holds for the noiseless ADC; with comparator noise the mapped
run draws per-tile keys and matches only in distribution.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.cim_linear import (
    CimStats,
    CiMConfig,
    _bitplane_matmul,
    _fake_quant_matmul,
    quantize_symmetric,
)
from repro.fabric.mapper import LayerPlacement, map_matmul
from repro.fabric.topology import FabricConfig

__all__ = ["execute_matmul", "execute_linear"]


def execute_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    fabric: FabricConfig,
    cim: CiMConfig,
    placement: Optional[LayerPlacement] = None,
    key: Optional[jax.Array] = None,
    return_stats: bool = False,
    use_kernel: bool = True,
):
    """``y = x @ w`` executed tile-wise over the mapped fabric placement.

    ``x``: (..., K); ``w``: (K, N). Matches ``cim_matmul(x, w, cim)``
    bit-for-bit in both ``bitplane`` and ``fake_quant`` modes (noiseless ADC).

    Example::

        >>> import jax
        >>> from repro.core.cim_linear import CiMConfig
        >>> from repro.fabric import FabricConfig, execute_matmul
        >>> fb = FabricConfig(mode="hybrid", n_arrays=12)
        >>> cim = CiMConfig(mode="bitplane", a_bits=4, w_bits=4, adc_bits=5, rows=16, ste=False)
        >>> x = jax.random.normal(jax.random.PRNGKey(0), (2, 40))
        >>> w = jax.random.normal(jax.random.PRNGKey(1), (40, 70))
        >>> execute_matmul(x, w, fb, cim).shape
        (2, 70)
    """
    if cim.mode not in ("bitplane", "fake_quant"):
        raise ValueError(f"fabric execution needs bitplane|fake_quant, got {cim.mode!r}")
    batch_shape = x.shape[:-1]
    k = x.shape[-1]
    n = w.shape[1]
    xm = x.reshape(-1, k)
    if placement is None:
        placement = map_matmul("matmul", xm.shape[0], k, n, fabric, cim=cim)
    if (placement.k, placement.n) != (k, n):
        raise ValueError(
            f"placement is for K={placement.k},N={placement.n}; got K={k},N={n}"
        )

    # fabric-level quantization: identical to the unmapped op's front-end
    x_int, sx = quantize_symmetric(xm, cim.a_bits, cim.a_signed)
    w_int, sw = quantize_symmetric(w, cim.w_bits, cim.w_signed, per_axis=-1)

    n_tiles = placement.n_tiles
    cols = fabric.cols
    parts = []  # scaled per-column-tile outputs (scaling is column-local,
    # so scaling a tile equals slicing the globally scaled result bit-for-bit)
    conversions = jnp.zeros((), jnp.int32)
    comparisons = jnp.zeros((), jnp.int32)
    for nt in range(n_tiles):
        n0, n1 = nt * cols, min((nt + 1) * cols, n)
        if cim.mode == "bitplane":
            tkey = jax.random.fold_in(key, nt) if key is not None else None
            y_tile, st = _bitplane_matmul(x_int, w_int[:, n0:n1], cim, tkey)
            conversions = conversions + st.conversions
            comparisons = comparisons + st.comparisons
            parts.append(y_tile * sx * sw[:, n0:n1])
        elif use_kernel:
            from repro.kernels.ops import cim_matmul_op

            # the fused kernel re-derives the same per-tensor / per-column
            # scales from the float operands and applies them itself
            parts.append(
                cim_matmul_op(
                    xm,
                    w[:, n0:n1],
                    rows=cim.rows,
                    adc_bits=cim.adc_bits,
                    mode="fake_quant",
                    a_bits=cim.a_bits,
                    w_bits=cim.w_bits,
                    a_signed=cim.a_signed,
                    w_signed=cim.w_signed,
                )
            )
        else:
            y_tile, _ = _fake_quant_matmul(x_int, w_int[:, n0:n1], cim)
            parts.append(y_tile * sx * sw[:, n0:n1])
    y_q = jnp.concatenate(parts, axis=1)

    if cim.ste:
        y_lin = xm @ w
        y_q = y_lin + jax.lax.stop_gradient(y_q - y_lin)

    y = y_q.reshape(*batch_shape, n)
    if return_stats:
        return y, CimStats(conversions, comparisons)
    return y


def execute_linear(
    x: jnp.ndarray,
    w: jnp.ndarray,
    bias: Optional[jnp.ndarray] = None,
    fabric: Optional[FabricConfig] = None,
    cim: Optional[CiMConfig] = None,
    placement: Optional[LayerPlacement] = None,
    key: Optional[jax.Array] = None,
):
    """Mapped counterpart of ``core.cim_linear.cim_linear``.

    Example::

        >>> import jax, jax.numpy as jnp
        >>> from repro.fabric import execute_linear
        >>> x = jax.random.normal(jax.random.PRNGKey(0), (4, 48))
        >>> w = jax.random.normal(jax.random.PRNGKey(1), (48, 40))
        >>> execute_linear(x, w, bias=jnp.zeros((40,))).shape
        (4, 40)
    """
    if fabric is None:
        fabric = FabricConfig()
    if cim is None:
        cim = CiMConfig(mode="bitplane", adc_bits=fabric.adc_bits, rows=fabric.rows, ste=False)
    y = execute_matmul(x, w, fabric, cim, placement=placement, key=key)
    if bias is not None:
        y = y + bias
    return y
