"""Numerically execute a mapped placement — batched, tile by tile.

The mapped path must be *bit-for-bit* the unmapped op: quantization scales
are computed once at the fabric level (per-tensor activations, per-column
weights — exactly ``core.cim_linear.cim_matmul``'s front-end), then every
output-column tile runs through the same per-plane machinery:

  * ``bitplane``   — ``core.cim_linear`` faithful per-plane path per tile
                     (noiseless memory-immersed ADC -> exact integer matmul
                     whenever ``2^adc_bits >= 2*rows``, as on the test chip);
  * ``fake_quant`` — the fused Pallas kernel (``kernels.ops.cim_matmul_op``)
                     per tile, interpret-mode on CPU.

K-tiling at ``rows`` boundaries happens *inside* the per-tile op and lands on
the same reduction slices the placement assigns to individual arrays, so the
per-array partial sums are the ones actually accumulated. Exact equality with
the unmapped op holds for the noiseless ADC; with comparator noise the mapped
run draws per-tile keys and matches only in distribution.

The per-column-tile inner loop itself lives in ``fabric.tiles`` — the single
definition shared with ``fabric.shard`` (both backends) and the fused
whole-model program (``fabric.program``).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.cim_linear import (
    CimStats,
    CiMConfig,
    quantize_symmetric,
)
from repro.fabric.mapper import LayerPlacement, map_matmul
from repro.fabric.tiles import analytic_cim_stats, column_tile_matmul
from repro.fabric.topology import FabricConfig
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

__all__ = ["execute_matmul", "execute_linear"]


def execute_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    fabric: FabricConfig,
    cim: CiMConfig,
    placement: Optional[LayerPlacement] = None,
    key: Optional[jax.Array] = None,
    return_stats: bool = False,
    use_kernel: bool = True,
):
    """``y = x @ w`` executed tile-wise over the mapped fabric placement.

    ``x``: (..., K); ``w``: (K, N). Matches ``cim_matmul(x, w, cim)``
    bit-for-bit in both ``bitplane`` and ``fake_quant`` modes (noiseless ADC).

    ``return_stats=True`` is meaningful in both modes: ``bitplane`` counts
    the conversions/comparisons actually performed; ``fake_quant`` (kernel or
    surrogate path) counts them analytically — tiles x plane-pairs x columns
    (``fabric.tiles.analytic_cim_stats``).

    Example::

        >>> import jax
        >>> from repro.core.cim_linear import CiMConfig
        >>> from repro.fabric import FabricConfig, execute_matmul
        >>> fb = FabricConfig(mode="hybrid", n_arrays=12)
        >>> cim = CiMConfig(mode="bitplane", a_bits=4, w_bits=4, adc_bits=5, rows=16, ste=False)
        >>> x = jax.random.normal(jax.random.PRNGKey(0), (2, 40))
        >>> w = jax.random.normal(jax.random.PRNGKey(1), (40, 70))
        >>> execute_matmul(x, w, fb, cim).shape
        (2, 70)
    """
    if cim.mode not in ("bitplane", "fake_quant"):
        raise ValueError(f"fabric execution needs bitplane|fake_quant, got {cim.mode!r}")
    batch_shape = x.shape[:-1]
    k = x.shape[-1]
    n = w.shape[1]
    xm = x.reshape(-1, k)
    if placement is None:
        placement = map_matmul("matmul", xm.shape[0], k, n, fabric, cim=cim)
    if (placement.k, placement.n) != (k, n):
        raise ValueError(
            f"placement is for K={placement.k},N={placement.n}; got K={k},N={n}"
        )

    # observability: host-side analytic accounting only (placement counts are
    # Python ints) — never reads traced values, so metrics cannot perturb
    # the compiled computation
    if obs_metrics.active():
        obs_metrics.inc("fabric_matmuls_total", help="Mapped matmuls executed.")
        obs_metrics.inc(
            "fabric_conversions_total",
            cim.a_bits * cim.w_bits * xm.shape[0] * placement.k_tiles * n,
            help="Analytic ADC conversions per executed matmul "
            "(planes x rows x k-tiles x columns).",
        )
    with obs_trace.span(
        "fabric.execute.matmul",
        layer=placement.name, m=xm.shape[0], k=k, n=n, mode=cim.mode,
    ):
        # fabric-level quantization: identical to the unmapped op's front-end
        x_int, sx = quantize_symmetric(xm, cim.a_bits, cim.a_signed)
        w_int, sw = quantize_symmetric(w, cim.w_bits, cim.w_signed, per_axis=-1)

        cols = fabric.cols
        if cim.mode == "fake_quant" and use_kernel:
            from repro.kernels.ops import cim_matmul_op

            # the fused kernel re-derives the same per-tensor / per-column
            # scales from the float operands and applies them itself
            parts = []
            for nt in range(placement.n_tiles):
                n0, n1 = nt * cols, min((nt + 1) * cols, n)
                parts.append(
                    cim_matmul_op(
                        xm,
                        w[:, n0:n1],
                        rows=cim.rows,
                        adc_bits=cim.adc_bits,
                        mode="fake_quant",
                        a_bits=cim.a_bits,
                        w_bits=cim.w_bits,
                        a_signed=cim.a_signed,
                        w_signed=cim.w_signed,
                    )
                )
            y_q = jnp.concatenate(parts, axis=1)
            # the kernel path performs the same tiles x plane-pairs x columns of
            # conversions as the faithful path — count them analytically
            stats = analytic_cim_stats(cim, xm.shape[0], placement.k_tiles, n)
            conversions, comparisons = stats.conversions, stats.comparisons
        else:
            y_int, stats = column_tile_matmul(x_int, w_int, cim, cols, key=key)
            conversions, comparisons = stats.conversions, stats.comparisons
            y_q = y_int * sx * sw

        if cim.ste:
            y_lin = xm @ w
            y_q = y_lin + jax.lax.stop_gradient(y_q - y_lin)

    y = y_q.reshape(*batch_shape, n)
    if return_stats:
        return y, CimStats(conversions, comparisons)
    return y


def execute_linear(
    x: jnp.ndarray,
    w: jnp.ndarray,
    bias: Optional[jnp.ndarray] = None,
    fabric: Optional[FabricConfig] = None,
    cim: Optional[CiMConfig] = None,
    placement: Optional[LayerPlacement] = None,
    key: Optional[jax.Array] = None,
):
    """Mapped counterpart of ``core.cim_linear.cim_linear``.

    Example::

        >>> import jax, jax.numpy as jnp
        >>> from repro.fabric import execute_linear
        >>> x = jax.random.normal(jax.random.PRNGKey(0), (4, 48))
        >>> w = jax.random.normal(jax.random.PRNGKey(1), (48, 40))
        >>> execute_linear(x, w, bias=jnp.zeros((40,))).shape
        (4, 40)
    """
    if fabric is None:
        fabric = FabricConfig()
    if cim is None:
        cim = CiMConfig(mode="bitplane", adc_bits=fabric.adc_bits, rows=fabric.rows, ste=False)
    y = execute_matmul(x, w, fabric, cim, placement=placement, key=key)
    if bias is not None:
        y = y + bias
    return y
