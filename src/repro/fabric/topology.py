"""Fabric topology: a chip of CiM arrays under one networking configuration.

A fabric is a grid of identical ``rows x cols`` bit-plane CiM arrays
(``core.cim_array``) partitioned into *digitization groups* — the paper's
networking neighborhoods (Fig. 1):

  * ``pair_sar``          — arrays pair up; partners alternate compute /
                            reference-generation roles each conversion (Fig. 2).
  * ``flash``             — a bank of 2^bits - 1 reference arrays serves
                            ``n_cim_per_group`` compute arrays; one comparison
                            cycle per conversion (Fig. 1 right).
  * ``hybrid``            — ``n_cim_per_group`` compute arrays take staggered
                            turns on a shared 2^flash_bits - 1 flash bank for
                            their MSBs, then pair off for SAR on the remaining
                            bits (Fig. 3, 5c).
  * ``conventional_sar``  — baseline: every array owns a dedicated SAR ADC
                            (40 nm anchor, Table I); no arrays are spent on
                            reference generation.
  * ``conventional_flash``— baseline with a dedicated Flash ADC per array.

Area accounting is anchored to ``core.energy_area`` (Table I): the in-memory
digitizer costs ~207.8 um^2 per array vs 5235.2 (SAR) / 10703.4 (Flash), which
is what lets an iso-area in-memory fabric pack ~25x/~51x cheaper digitization
and therefore more arrays.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.energy_area import area_um2

__all__ = [
    "FabricConfig",
    "ChipMeshConfig",
    "arrays_for_area",
    "MODES",
    "BITCELL_UM2_65NM",
]

MODES = ("pair_sar", "flash", "hybrid", "conventional_sar", "conventional_flash")

# 65 nm 8T compute-SRAM bitcell (~1.9 um^2) plus ~15% periphery (WL/IL drivers,
# precharge, transmission gates) — the bare array cost one digitizer rides on.
BITCELL_UM2_65NM = 1.9
_PERIPHERY_FACTOR = 1.15

# External-memory (weight reload) energy anchor, pJ per bit (LPDDR-class).
EMA_PJ_PER_BIT = 10.0


@dataclasses.dataclass(frozen=True)
class FabricConfig:
    """Static description of one chip-level CiM fabric.

    A grid of ``rows x cols`` bit-plane CiM arrays partitioned into
    digitization groups under one networking ``mode`` (see module docstring);
    sized either by an explicit ``n_arrays`` or an ``area_budget_um2``
    (whole groups only).

    Example::

        >>> fb = FabricConfig(mode="hybrid", adc_bits=5, flash_bits=2, n_arrays=64)
        >>> fb.group_size, fb.resolved_n_arrays(), fb.n_compute_arrays
        (6, 60, 30)
    """

    mode: str = "hybrid"
    rows: int = 16  # word lines per array (reduction-tile size)
    cols: int = 32  # columns per array (output channels per tile)
    adc_bits: int = 5
    flash_bits: int = 2  # MSBs on the shared flash bank (hybrid only)
    n_cim_per_group: int = 3  # compute arrays sharing one reference bank
    n_arrays: Optional[int] = None  # explicit total array count
    area_budget_um2: Optional[float] = None  # derive n_arrays from a budget
    freq_hz: float = 10e6  # conversion-cycle clock (Table I anchor)
    vdd: float = 1.0

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"unknown fabric mode {self.mode!r}; pick from {MODES}")
        if self.mode == "hybrid" and not (0 < self.flash_bits < self.adc_bits):
            raise ValueError("hybrid mode needs 0 < flash_bits < adc_bits")
        if self.n_cim_per_group < 1:
            raise ValueError("n_cim_per_group must be >= 1")
        if self.n_arrays is None and self.area_budget_um2 is None:
            object.__setattr__(self, "n_arrays", 64)
        if self.n_arrays is not None and self.n_arrays < self.group_size:
            raise ValueError(
                f"need at least one full group ({self.group_size} arrays), "
                f"got n_arrays={self.n_arrays}"
            )

    # -- group structure ----------------------------------------------------

    @property
    def n_ref_per_group(self) -> int:
        """Arrays per group spent generating references (not computing)."""
        if self.mode == "pair_sar":
            return 0  # partners swap roles; both compute at half duty
        if self.mode == "flash":
            return (1 << self.adc_bits) - 1
        if self.mode == "hybrid":
            return (1 << self.flash_bits) - 1
        return 0  # conventional: dedicated ADC, no arrays stolen

    @property
    def compute_arrays_per_group(self) -> int:
        if self.mode == "pair_sar":
            return 2
        if self.mode.startswith("conventional"):
            return 1
        return self.n_cim_per_group

    @property
    def group_size(self) -> int:
        return self.compute_arrays_per_group + self.n_ref_per_group

    # -- sizing -------------------------------------------------------------

    @property
    def adc_style(self) -> str:
        """core.energy_area style for this fabric's digitizer."""
        return {
            "pair_sar": "in_memory",
            "flash": "in_memory_flash",
            "hybrid": "in_memory_hybrid",
            "conventional_sar": "sar",
            "conventional_flash": "flash",
        }[self.mode]

    @property
    def array_area_um2(self) -> float:
        return self.rows * self.cols * BITCELL_UM2_65NM * _PERIPHERY_FACTOR

    @property
    def digitizer_area_um2(self) -> float:
        """Per-array digitization area (comparator + gates, or dedicated ADC)."""
        return area_um2(self.adc_style, self.adc_bits)

    @property
    def per_array_area_um2(self) -> float:
        return self.array_area_um2 + self.digitizer_area_um2

    def resolved_n_arrays(self) -> int:
        """Array count, floored to whole digitization groups."""
        if self.n_arrays is not None:
            n = self.n_arrays
        else:
            # epsilon guards exact-multiple budgets against fp division slop
            n = int(self.area_budget_um2 / self.per_array_area_um2 + 1e-9)
        n_groups = n // self.group_size
        if n_groups < 1:
            raise ValueError(
                f"budget fits {n} arrays < one {self.mode} group of {self.group_size}"
            )
        return n_groups * self.group_size

    @property
    def n_groups(self) -> int:
        return self.resolved_n_arrays() // self.group_size

    @property
    def n_compute_arrays(self) -> int:
        return self.n_groups * self.compute_arrays_per_group

    def chip_area_um2(self) -> float:
        return self.resolved_n_arrays() * self.per_array_area_um2

    def chip_adc_area_um2(self) -> float:
        return self.resolved_n_arrays() * self.digitizer_area_um2

    def weight_capacity_bits(self) -> int:
        """Raw weight-bit capacity of the compute arrays (one bitcell holds
        one weight-plane bit; a w_bits weight occupies w_bits cells)."""
        return self.n_compute_arrays * self.rows * self.cols

    def iso_area_counterpart(self) -> "FabricConfig":
        """The conventional-ADC fabric occupying the same chip area.

        pair_sar / hybrid compare against dedicated SAR; flash against
        dedicated Flash (the paper's two Table I baselines).
        """
        if self.mode.startswith("conventional"):
            raise ValueError("already a conventional baseline")
        base = "conventional_flash" if self.mode == "flash" else "conventional_sar"
        return dataclasses.replace(
            self,
            mode=base,
            n_arrays=None,
            area_budget_um2=self.chip_area_um2(),
        )


def arrays_for_area(budget_um2: float, fabric: FabricConfig) -> int:
    """How many arrays (whole groups) of this fabric style fit in a budget.

    Example::

        >>> fb = FabricConfig(mode="pair_sar", n_arrays=2)
        >>> arrays_for_area(10 * fb.per_array_area_um2, fb)
        10
    """
    return dataclasses.replace(
        fabric, n_arrays=None, area_budget_um2=budget_um2
    ).resolved_n_arrays()


@dataclasses.dataclass(frozen=True)
class ChipMeshConfig:
    """A mesh of identical CiM chips the fabric shards across.

    Two named axes mirror :func:`repro.launch.mesh.make_chip_mesh` (and the
    production training/serving meshes): ``model`` chips split a layer's
    K-parallel reduction tiles and combine their partial product-sums with a
    reduce-scatter over the inter-chip links; ``data`` chips replicate the
    weights and split the batch. ``fabric`` describes every chip (one
    :class:`FabricConfig`), so chip-local area/energy/latency roll up
    unchanged while the link parameters price the new cross-chip traffic
    that ``fabric.report`` reports separately from on-chip EMA.

    Example::

        >>> cm = ChipMeshConfig(data=2, model=2, fabric=FabricConfig(mode="hybrid"))
        >>> cm.n_chips
        4
        >>> cm.mesh().axis_names
        ('data', 'model')
    """

    data: int = 1  # batch-parallel chips (weights replicated)
    model: int = 1  # K-parallel chips (partial sums reduce-scattered)
    fabric: FabricConfig = FabricConfig()
    link_bits_per_s: float = 32e9  # per-chip inter-chip link bandwidth
    link_pj_per_bit: float = 1.0  # SerDes-class link energy
    psum_bits: int = 24  # partial-sum word width on the links

    def __post_init__(self):
        if self.data < 1 or self.model < 1:
            raise ValueError(
                f"mesh axes must be >= 1, got data={self.data}, model={self.model}"
            )
        if self.psum_bits < 1:
            raise ValueError("psum_bits must be >= 1")

    @property
    def n_chips(self) -> int:
        return self.data * self.model

    @property
    def shape(self) -> tuple:
        return (self.data, self.model)

    def mesh(self):
        """The jax ``(data, model)`` mesh (abstract when devices are scarce)."""
        from repro.launch.mesh import make_chip_mesh

        return make_chip_mesh(self.data, self.model)

    def total_area_um2(self) -> float:
        return self.n_chips * self.fabric.chip_area_um2()

    def total_weight_capacity_bits(self) -> int:
        """Distinct weight bits the mesh can hold resident: ``model`` chips
        hold different K-slices, ``data`` chips hold copies."""
        return self.model * self.fabric.weight_capacity_bits()
