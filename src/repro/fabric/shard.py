"""Shard mapped CiM fabrics across a mesh of chips (ROADMAP: multi-chip).

One chip (``FabricConfig``) holds a bounded number of resident weight tiles;
the paper's system argument — cheap memory-immersed digitization buys more
arrays, more resident weights, fewer external memory accesses — extends to a
*mesh* of such chips (:class:`repro.fabric.topology.ChipMeshConfig`):

  * ``model`` axis — a layer's K-parallel reduction tiles are split across
    chips at ``rows`` boundaries. Each chip digitizes the partial
    product-sums of its own K-slice locally (nothing analog ever crosses a
    chip boundary); the digital partials are combined with a ring
    **reduce-scatter** over the inter-chip links — the only new traffic the
    mesh introduces, priced separately from on-chip EMA in
    ``fabric.report``.
  * ``data`` axis — chips hold weight copies and split the batch (M); no
    cross-chip combine is needed.

Divisibility follows the production sharding rules: the split is planned with
``launch.shardings.spec_for`` (logical ``tp`` -> mesh ``model``, ``dp`` ->
``data``), and any dimension that does not divide its axis falls back to
replication *with the fallback recorded* — the same bookkeeping the dry-run
report uses, so an uneven layer silently costs nothing extra instead of
silently mis-mapping.

Numerics: :func:`execute_sharded_matmul` mirrors ``fabric.execute`` exactly —
fabric-level quantization once, then per (data-shard, column-tile, K-shard)
tile execution through ``core.cim_linear``'s per-plane machinery. On a 1x1
mesh it performs the identical operation sequence, so it is bit-for-bit equal
to the unsharded ``execute_matmul`` (asserted in ``tests/test_fabric_shard``).

Execution backends: ``backend="sequential"`` simulates every chip in a host
Python loop (runs anywhere); ``backend="shard_map"`` places the chips on a
real ``(data, model)`` jax device mesh (``launch.mesh.make_chip_mesh``) and
runs them as one SPMD program — each model-axis device computes its K-slice
partial sums locally and the digital combine is a ``jax.lax.psum_scatter``
reduce-scatter (+ gather) over the ``model`` axis, the collective whose link
traffic ``ShardedPlacement.crosschip_bits_per_pass`` prices. ``"auto"``
(default) picks ``shard_map`` whenever the host has enough devices and the
plan has no replication fallbacks, else falls back to the sequential loop.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.cim_linear import (
    CimStats,
    CiMConfig,
    quantize_symmetric,
)
from repro.fabric.mapper import LayerPlacement, map_matmul, model_matmuls
from repro.fabric.tiles import column_tile_matmul
from repro.fabric.topology import ChipMeshConfig
from repro.launch import shardings as sh
from repro.launch.mesh import make_chip_mesh
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.fallback import REASON_RAGGED_BATCH, classify_fallback, record_fallback

__all__ = [
    "ShardedPlacement",
    "shard_placement",
    "shard_model",
    "resolve_backend",
    "execute_sharded_matmul",
]

BACKENDS = ("auto", "sequential", "shard_map")


@dataclasses.dataclass
class ShardedPlacement:
    """One layer's placement on a chip mesh, plus its cross-chip costs.

    ``chip`` is the per-chip :class:`~repro.fabric.mapper.LayerPlacement` of
    the K/M shard every chip actually executes (on a 1x1 mesh it is the whole
    layer). ``k_splits`` / ``d_splits`` are the *realized* split factors —
    equal to the mesh axes when the tile/batch counts divide, 1 (replication)
    when they don't, with each fallback recorded in ``fallbacks``.

    Example::

        >>> from repro.fabric import ChipMeshConfig, FabricConfig, shard_placement, map_matmul
        >>> cm = ChipMeshConfig(model=2, fabric=FabricConfig(mode="pair_sar", n_arrays=8))
        >>> sp = shard_placement(map_matmul("l", 4, 64, 64, cm.fabric), cm)
        >>> sp.k_splits, sp.chip.k_tiles, sp.crosschip_bits_per_pass > 0
        (2, 2, True)
    """

    name: str
    m: int
    k: int
    n: int
    chip_mesh: ChipMeshConfig
    chip: LayerPlacement  # what ONE chip runs (K/M shard mapped on its fabric)
    k_splits: int  # chips combining partial sums over the model axis
    d_splits: int  # batch shards over the data axis
    fallbacks: List[str]

    # -- cross-chip traffic (the mesh's only new cost) ----------------------

    @property
    def crosschip_bits_per_pass(self) -> int:
        """Total bits crossing chip links per forward pass: a ring
        reduce-scatter over ``k_splits`` chips moves ``(C-1)/C`` of each
        chip's (M_shard, N) partial-sum block, summed over chips and repeated
        per data-shard group — ``(C-1) * M * N * psum_bits`` in total."""
        if self.k_splits <= 1:
            return 0
        return (self.k_splits - 1) * self.m * self.n * self.chip_mesh.psum_bits

    @property
    def crosschip_energy_pj(self) -> float:
        return self.crosschip_bits_per_pass * self.chip_mesh.link_pj_per_bit

    @property
    def crosschip_latency_s(self) -> float:
        """Link time of the reduce-scatter: rings run in parallel across data
        groups, so the critical path is one chip's send volume."""
        if self.k_splits <= 1:
            return 0.0
        per_chip = (
            (self.k_splits - 1)
            / self.k_splits
            * (self.m // self.d_splits)
            * self.n
            * self.chip_mesh.psum_bits
        )
        return per_chip / self.chip_mesh.link_bits_per_s

    @property
    def n_chips_active(self) -> int:
        return self.k_splits * self.d_splits


def _k_slice(k: int, rows: int, k_tiles: int, k_splits: int, c: int) -> tuple:
    """Element range [k0, k1) of K-shard ``c`` (tile-granular, ragged tail)."""
    tiles_per = k_tiles // k_splits
    return c * tiles_per * rows, min(k, (c + 1) * tiles_per * rows)


def shard_placement(
    placement: LayerPlacement,
    chip_mesh: ChipMeshConfig,
    array_offset: int = 0,
) -> ShardedPlacement:
    """Partition one mapped layer across the chip mesh.

    K-parallel tiles go over the ``model`` axis, batch rows over ``data``,
    using the same ``spec_for`` divisibility rules (and scoped
    ``record_fallbacks`` bookkeeping) as the production param shardings: a
    K-tile count that does not divide the model axis — or a batch that does
    not divide the data axis — falls back to replication for that dimension.

    Example::

        >>> from repro.fabric import ChipMeshConfig, FabricConfig, map_matmul, shard_placement
        >>> fb = FabricConfig(mode="pair_sar", n_arrays=8)
        >>> sp = shard_placement(map_matmul("l", 4, 64, 64, fb), ChipMeshConfig(model=4, fabric=fb))
        >>> sp.k_splits, sp.chip.k
        (4, 16)
    """
    if placement.fabric != chip_mesh.fabric:
        raise ValueError("placement was mapped on a different FabricConfig than chip_mesh.fabric")
    mesh = chip_mesh.mesh()
    with sh.record_fallbacks() as fallbacks:
        spec = sh.spec_for(
            mesh,
            (placement.k_tiles, placement.m),
            ("tp", "dp"),
            label=f"fabric.shard/{placement.name}",
        )
    k_splits = sh.axes_size(mesh, ("model",)) if spec[0] is not None else 1
    d_splits = sh.axes_size(mesh, ("data",)) if spec[1] is not None else 1

    if k_splits == 1 and d_splits == 1 and array_offset == 0:
        chip = placement  # whole layer on every chip — exactly the 1-chip map
    else:
        k0, k1 = _k_slice(placement.k, placement.fabric.rows, placement.k_tiles, k_splits, 0)
        chip = map_matmul(
            placement.name,
            placement.m // d_splits,
            k1 - k0,
            placement.n,
            chip_mesh.fabric,
            cim=placement.cim,
            array_offset=array_offset,
        )
    return ShardedPlacement(
        name=placement.name,
        m=placement.m,
        k=placement.k,
        n=placement.n,
        chip_mesh=chip_mesh,
        chip=chip,
        k_splits=k_splits,
        d_splits=d_splits,
        fallbacks=fallbacks,
    )


def shard_model(
    cfg: ModelConfig,
    chip_mesh: ChipMeshConfig,
    tokens: int = 1,
    cim: Optional[CiMConfig] = None,
    block_only: bool = False,
    matmuls: Optional[List[tuple]] = None,
) -> List[ShardedPlacement]:
    """Map every linear of ``cfg`` onto the mesh (``map_model`` per chip-shard,
    round-robin array offsets preserved across layers).

    ``matmuls`` overrides the ``(name, M, K, N)`` list (default: all of
    ``model_matmuls``) — ``fabric.program`` passes the forward chain through
    here so both planners share ONE offset-bookkeeping walk.

    Example::

        >>> from repro.configs.registry import get_config
        >>> from repro.fabric import ChipMeshConfig, FabricConfig, shard_model
        >>> cm = ChipMeshConfig(model=4, fabric=FabricConfig(mode="hybrid", n_arrays=60))
        >>> sps = shard_model(get_config("smollm-135m"), cm, tokens=4, block_only=True)
        >>> len(sps), sps[0].k_splits
        (7, 4)
    """
    if matmuls is None:
        matmuls = model_matmuls(cfg, tokens, block_only=block_only)
    out: List[ShardedPlacement] = []
    offset = 0
    for name, m, k, n in matmuls:
        p = map_matmul(name, m, k, n, chip_mesh.fabric, cim=cim)
        sp = shard_placement(p, chip_mesh, array_offset=offset)
        offset = (offset + sp.chip.n_weight_tiles) % chip_mesh.fabric.n_compute_arrays
        out.append(sp)
    return out


def _chip_noise_key(key: Optional[jax.Array], chip_index):
    """Per-chip ADC noise key: ``fold_in(key, chip_index)`` for every chip
    except chip 0, which keeps the caller's key unchanged — so a 1x1 mesh
    reproduces the unsharded path's per-tile ``fold_in(key, nt)`` draws
    bit-for-bit while every other chip gets an independent stream.

    ``chip_index`` is the K-shard (model-axis) index only: chips along the
    data axis share the key and are distinguished instead by the global row
    ids threaded through ``column_tile_matmul``'s ``row_offset``, which makes
    each batch row's draws invariant to the batch size and data split — the
    property ``fabric.autotune``'s zero-padded bucketed batches rely on.

    Accepts a Python int (sequential backend) or a traced ``axis_index``
    scalar (shard_map backend); both derivations are identical, which is what
    keeps the two backends' noise draws equal.
    """
    if key is None:
        return None
    if isinstance(chip_index, int):
        return key if chip_index == 0 else jax.random.fold_in(key, chip_index)
    return jax.lax.cond(
        chip_index == 0,
        lambda: key,
        lambda: jax.random.fold_in(key, chip_index),
    )


def resolve_backend(sharded: ShardedPlacement, backend: str = "auto") -> str:
    """Resolve the execution backend for a sharded plan.

    ``shard_map`` needs (a) a concrete device mesh — ``data * model`` jax
    devices on the host — and (b) a plan with no replication fallbacks (the
    realized ``d_splits x k_splits`` must equal the mesh shape, or devices
    along a replicated axis would double-count partial sums). ``"auto"``
    falls back to ``"sequential"`` when either is missing — and also on a
    1x1 mesh, where there is nothing to parallelize and the SPMD dispatch
    is pure overhead; an explicit ``backend="shard_map"`` runs it anyway
    (the 1x1 bit-exactness tests do exactly that) or raises with the
    reasons when ineligible.

    Example::

        >>> from repro.fabric import ChipMeshConfig, FabricConfig, map_matmul, shard_placement
        >>> fb = FabricConfig(mode="pair_sar", n_arrays=8)
        >>> sp = shard_placement(map_matmul("l", 4, 64, 64, fb), ChipMeshConfig(fabric=fb))
        >>> resolve_backend(sp, "auto") in ("sequential", "shard_map")
        True
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; pick from {BACKENDS}")
    if backend == "sequential":
        return "sequential"
    cm = sharded.chip_mesh
    problems = []
    n_dev = len(jax.devices())
    if n_dev < cm.n_chips:
        problems.append(
            f"host has {n_dev} jax device(s) < {cm.n_chips} chips (set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={cm.n_chips})"
        )
    if (sharded.d_splits, sharded.k_splits) != (cm.data, cm.model):
        problems.append(
            f"replication fallbacks leave realized splits "
            f"{sharded.d_splits}x{sharded.k_splits} != mesh {cm.data}x{cm.model}"
        )
    if problems:
        if backend == "shard_map":
            raise ValueError("shard_map backend unavailable: " + "; ".join(problems))
        # auto -> sequential: a real degradation, recorded as a structured
        # fallback (no-op unless repro.obs tracing/metrics are active)
        record_fallback(
            "fabric.shard", classify_fallback(problems), "; ".join(problems)
        )
        return "sequential"
    if backend == "auto" and cm.n_chips == 1:
        return "sequential"  # single chip: SPMD dispatch is pure overhead
    return "shard_map"


def _shard_map_matmul(x_int, w_int, sx, sw, sharded: ShardedPlacement, cim: CiMConfig, key):
    """One SPMD program over the concrete ``(data, model)`` device mesh.

    Each device holds its chip's batch rows and K-slice, runs the same
    per-column-tile ``core.cim_linear`` machinery as the sequential loop, and
    the digital combine over the ``model`` axis is the physical collective:
    a ``psum_scatter`` reduce-scatter (the ``(C-1) * M * N * psum_bits`` link
    traffic of ``crosschip_bits_per_pass``) followed by the gather that
    redistributes the combined rows. Scales are applied after the combine —
    the partial sums are integer-valued, so the sum is exact and the 1x1 mesh
    stays bit-for-bit equal to the unsharded path.
    """
    fabric = sharded.chip_mesh.fabric
    k_splits, d_splits = sharded.k_splits, sharded.d_splits
    n = w_int.shape[1]
    cols = fabric.cols
    k_tiles = math.ceil(sharded.k / fabric.rows)
    mesh = make_chip_mesh(d_splits, k_splits, require_concrete=True)

    # pad K to whole tiles so every model-axis device gets an equal block;
    # _bitplane_matmul pads the ragged tail identically in the sequential path
    k_pad = k_tiles * fabric.rows - x_int.shape[1]
    if k_pad:
        x_int = jnp.pad(x_int, ((0, 0), (0, k_pad)))
        w_int = jnp.pad(w_int, ((0, k_pad), (0, 0)))

    has_key = key is not None

    def chip_fn(x_blk, w_blk, sx_, sw_, *maybe_key):
        di = jax.lax.axis_index("data")
        ci = jax.lax.axis_index("model")
        # the chip key carries only the K-shard index: data-axis chips are
        # told apart by the global ROW ids they pass down (row_offset), so a
        # row's draws do not move when the batch split changes
        chip_key = _chip_noise_key(maybe_key[0], ci) if has_key else None
        # this chip's K-partial, (m_shard, N) — the one shared inner loop
        y_local, st = column_tile_matmul(
            x_blk, w_blk, cim, cols, key=chip_key,
            row_offset=di * x_blk.shape[0],
        )
        conversions, comparisons = st.conversions, st.comparisons
        if k_splits > 1:
            if n % k_splits == 0:
                # the modeled ring reduce-scatter, then the gather that hands
                # every chip the combined rows back
                y_sc = jax.lax.psum_scatter(
                    y_local, "model", scatter_dimension=1, tiled=True
                )
                y_sum = jax.lax.all_gather(y_sc, "model", axis=1, tiled=True)
            else:
                y_sum = jax.lax.psum(y_local, "model")
        else:
            y_sum = y_local
        conversions = jax.lax.psum(conversions, ("data", "model"))
        comparisons = jax.lax.psum(comparisons, ("data", "model"))
        return y_sum * sx_ * sw_, conversions, comparisons

    in_specs = [P("data", "model"), P("model", None), P(), P(None, None)]
    args = [x_int, w_int, sx, sw]
    if has_key:
        in_specs.append(P())
        args.append(key)
    fn = shard_map(
        chip_fn,
        mesh,
        in_specs=tuple(in_specs),
        out_specs=(P("data", None), P(), P()),
        check_rep=False,
    )
    return fn(*args)


def execute_sharded_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    chip_mesh: ChipMeshConfig,
    cim: CiMConfig,
    sharded: Optional[ShardedPlacement] = None,
    key: Optional[jax.Array] = None,
    return_stats: bool = False,
    backend: str = "auto",
):
    """``y = x @ w`` executed shard-wise over the chip mesh.

    Quantization scales are global (fabric-level calibration), so every chip
    computes integer partial product-sums over its own K-slice and the
    reduce-scatter combine is a plain digital sum — on a 1x1 mesh the
    operation sequence is identical to ``fabric.execute.execute_matmul`` and
    the result is bit-for-bit equal (bitplane and fake_quant, noiseless ADC).

    ``backend`` selects how the chips run (see :func:`resolve_backend`):
    ``"sequential"`` simulates them in a host loop, ``"shard_map"`` places
    them on a real jax device mesh and combines partials with the
    ``psum_scatter`` reduce-scatter the traffic model prices, ``"auto"``
    (default) uses shard_map when the host has the devices and the plan has
    no fallbacks. The two backends draw identical per-chip ADC noise keys
    (:func:`_chip_noise_key`), so they agree to float tolerance on any mesh
    and bit-for-bit on 1x1.

    ``x``: (..., K); ``w``: (K, N). Per-chip shards run through the same
    ``core.cim_linear`` per-plane machinery as the single-chip path; the
    Pallas kernel path is not used here because it re-derives quantization
    scales per call, which would differ per K-slice.

    Example::

        >>> import jax, jax.numpy as jnp
        >>> from repro.core.cim_linear import CiMConfig
        >>> from repro.fabric import ChipMeshConfig, FabricConfig, execute_sharded_matmul
        >>> cm = ChipMeshConfig(model=2, fabric=FabricConfig(mode="pair_sar", n_arrays=8))
        >>> cim = CiMConfig(mode="bitplane", a_bits=4, w_bits=4, adc_bits=5, rows=16, ste=False)
        >>> x = jax.random.normal(jax.random.PRNGKey(0), (4, 64))
        >>> w = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
        >>> execute_sharded_matmul(x, w, cm, cim).shape
        (4, 32)
    """
    if cim.mode not in ("bitplane", "fake_quant"):
        raise ValueError(f"fabric execution needs bitplane|fake_quant, got {cim.mode!r}")
    fabric = chip_mesh.fabric
    batch_shape = x.shape[:-1]
    k = x.shape[-1]
    n = w.shape[1]
    xm = x.reshape(-1, k)
    if sharded is None:
        base = map_matmul("matmul", xm.shape[0], k, n, fabric, cim=cim)
        sharded = shard_placement(base, chip_mesh)
    if sharded.chip_mesh != chip_mesh:
        raise ValueError("sharded placement was planned on a different ChipMeshConfig")
    if (sharded.k, sharded.n) != (k, n):
        raise ValueError(
            f"sharded placement is for K={sharded.k},N={sharded.n}; got K={k},N={n}"
        )
    requested = backend
    backend = resolve_backend(sharded, backend)
    if backend == "shard_map" and xm.shape[0] % sharded.d_splits:
        # the plan was made for a divisible batch; a ragged runtime batch can
        # only run on the sequential loop (last shard takes the remainder)
        if requested == "shard_map":
            raise ValueError(
                f"shard_map backend unavailable: batch rows {xm.shape[0]} are "
                f"not divisible by the data axis ({sharded.d_splits})"
            )
        record_fallback(
            "fabric.shard", REASON_RAGGED_BATCH,
            f"batch rows {xm.shape[0]} % data axis {sharded.d_splits} != 0",
        )
        backend = "sequential"
    if obs_metrics.active():
        # host-side analytic accounting only: the sharded chips jointly
        # perform the same planes x rows x k-tiles x columns of conversions
        # as the unsharded op, and the link bits are the placement's
        # (C-1) * M * N * psum_bits reduce-scatter traffic
        obs_metrics.inc("fabric_matmuls_total", help="Mapped matmuls executed.")
        obs_metrics.inc(
            "fabric_conversions_total",
            cim.a_bits * cim.w_bits * xm.shape[0] * math.ceil(k / fabric.rows) * n,
            help="Analytic ADC conversions per executed matmul "
            "(planes x rows x k-tiles x columns).",
        )
        obs_metrics.inc(
            "fabric_link_bits_total",
            sharded.crosschip_bits_per_pass,
            help="Cross-chip reduce-scatter bits moved per executed matmul.",
        )
    span = obs_trace.span(
        "fabric.shard.matmul",
        layer=sharded.name, m=xm.shape[0], k=k, n=n,
        backend=backend, mesh=f"{sharded.d_splits}x{sharded.k_splits}",
    )
    k_splits, d_splits = sharded.k_splits, sharded.d_splits
    k_tiles = math.ceil(k / fabric.rows)
    cols = fabric.cols

    with span:
        # fabric-level quantization: global scales, exactly the unsharded
        # front-end
        x_int, sx = quantize_symmetric(xm, cim.a_bits, cim.a_signed)
        w_int, sw = quantize_symmetric(w, cim.w_bits, cim.w_signed, per_axis=-1)

        if backend == "shard_map":
            y_q, conversions, comparisons = _shard_map_matmul(
                x_int, w_int, sx, sw, sharded, cim, key
            )
        else:
            m_total = xm.shape[0]
            m_shard = m_total // d_splits if d_splits > 1 else m_total
            conversions = jnp.zeros((), jnp.int32)
            comparisons = jnp.zeros((), jnp.int32)
            data_parts = []
            for d in range(d_splits):
                m0 = d * m_shard
                m1 = (d + 1) * m_shard if d < d_splits - 1 else m_total
                x_d = x_int[m0:m1]
                total = None
                for c in range(k_splits):
                    k0, k1 = _k_slice(k, fabric.rows, k_tiles, k_splits, c)
                    chip_key = _chip_noise_key(key, c)
                    y_c, st = column_tile_matmul(
                        x_d[:, k0:k1], w_int[k0:k1], cim, cols,
                        key=chip_key, row_offset=m0,
                    )
                    conversions = conversions + st.conversions
                    comparisons = comparisons + st.comparisons
                    # digital partial-sum combine == the reduce-scatter's sum
                    total = y_c if total is None else total + y_c
                data_parts.append(total * sx * sw)
            y_q = jnp.concatenate(data_parts, axis=0)

        if cim.ste:
            y_lin = xm @ w
            y_q = y_lin + jax.lax.stop_gradient(y_q - y_lin)

    y = y_q.reshape(*batch_shape, n)
    if return_stats:
        return y, CimStats(conversions, comparisons)
    return y
