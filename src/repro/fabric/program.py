"""Whole-model fused forward: compile a mapped chain into ONE shard_map program.

``fabric.shard.execute_sharded_matmul`` runs one matmul at a time: every layer
gathers its combined output to the host, re-scatters it as the next layer's
input, and pays a Python dispatch. The paper's area argument is system-level —
memory-immersed digitization buys more resident arrays per chip, which only
pays off if the *whole network* runs on the fabric with minimal external
traffic — so this module compiles the entire forward pass into a single
jitted SPMD program:

  * layer i's ``psum_scatter`` output **stays sharded** as layer i+1's input —
    the reduce-scatter leaves chip ``c`` holding exactly the output columns
    that are its K-slice of the next layer (tile-aligned by construction), so
    no gather/re-scatter happens between layers and ONE ``all_gather`` at the
    very end produces the full output;
  * inter-layer re-quantization stays sharded too: the global activation
    abs-max is a scalar ``pmax`` over the mesh (max of shard maxes IS the
    global max, exactly), so the fused program quantizes bit-identically to
    the per-layer loop's host-side ``quantize_symmetric``;
  * per-layer ADC noise keys are ``fold_in(key, layer_index)``-derived, then
    per-chip / per-tile like every other executor (``fabric.tiles``), so a
    1x1 mesh is bit-for-bit the per-layer ``execute_sharded_matmul`` loop —
    noisy ADC included — and a multi-chip mesh matches it to float tolerance.

:func:`measure_forward` closes the validation loop the ROADMAP asks for: it
wall-clocks the fused collectives (block-until-ready, fused program minus an
identical program with the collectives stripped) and reports the measured
time next to ``overlapped_mesh_latency``'s modeled link time
(``fabric.pipeline.link_validation``). The two live in different clock
domains — host-simulation seconds vs modeled 10 MHz-fabric seconds — so the
ratio is a calibration constant tracked across PRs (``tools/ci_check.py`` ->
``BENCH_fabric_program.json``), not a number expected to be 1.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.cim_linear import CimStats, CiMConfig, quantize_symmetric
from repro.fabric.mapper import model_forward_chain
from repro.fabric.shard import (
    ShardedPlacement,
    _chip_noise_key,
    execute_sharded_matmul,
    shard_model,
)
from repro.fabric.tiles import column_tile_matmul
from repro.fabric.topology import ChipMeshConfig
from repro.launch.mesh import make_chip_mesh
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.fallback import (
    REASON_RAGGED_BATCH,
    REASON_REQUESTED_SEQUENTIAL,
    classify_fallback,
    record_fallback,
)

__all__ = [
    "FabricProgram",
    "compile_forward",
    "per_layer_forward",
    "measure_forward",
    "program_eligibility",
]


def _record_request(component: str, program, m: int, fused: bool) -> None:
    """Host-side per-request accounting shared by the chain and graph
    programs: one ``fabric_requests_total{path=...}`` increment, plus — on
    the fused path only, whose collectives never pass through
    ``execute_sharded_matmul`` — the analytic conversion/link-bit totals the
    per-layer loop would otherwise record matmul by matmul. Reads nothing
    traced; no-op when metrics collection is inactive."""
    if not obs_metrics.active():
        return
    obs_metrics.inc(
        "fabric_requests_total",
        help="Forward requests by execution path (fused shard_map vs fallback loop).",
        path="fused" if fused else "fallback",
    )
    if fused:
        cim = program.cim
        rows = program.chip_mesh.fabric.rows
        obs_metrics.inc(
            "fabric_matmuls_total",
            len(program.placements),
            help="Mapped matmuls executed.",
        )
        obs_metrics.inc(
            "fabric_conversions_total",
            sum(
                cim.a_bits * cim.w_bits * m * math.ceil(sp.k / rows) * sp.n
                for sp in program.placements
            ),
            help="Analytic ADC conversions per executed matmul "
            "(planes x rows x k-tiles x columns).",
        )
        obs_metrics.inc(
            "fabric_link_bits_total",
            # crosschip_bits_per_pass is priced at the placement's planned M;
            # scale to the rows actually served — exact, since the bits are
            # (k_splits-1) * M * N * psum_bits, linear in M
            sum(
                sp.crosschip_bits_per_pass * m // sp.m
                for sp in program.placements
            ),
            help="Cross-chip reduce-scatter bits moved per executed matmul.",
        )


def _record_request_fallback(component: str, program, detail: str = "") -> None:
    """Classify and emit the structured fallback record for a request that
    left the fused path (``__call__``'s sequential branches)."""
    if program.problems:
        reason = classify_fallback(program.problems)
        detail = detail or "; ".join(program.problems)
    elif program.requested_backend == "sequential":
        reason = REASON_REQUESTED_SEQUENTIAL
    else:
        reason = REASON_RAGGED_BATCH
    record_fallback(component, reason, detail)

_COLLECTIVE_PRIMS = ("all_gather", "reduce_scatter", "psum", "pmax", "ppermute", "all_to_all")


def _count_collectives(fn, args) -> dict:
    """Count collective primitives in ``fn``'s jaxpr (recursing into nested
    jaxprs) — shared by the chain program's and the graph program's
    collective census. A ``lax.scan`` body executes once per iteration, so
    the walk multiplies everything inside it by the scan's trip count: a
    scan-over-layers program therefore reports its per-block census x
    ``n_layers``, directly comparable to the unrolled program's budget."""
    jaxpr = jax.make_jaxpr(fn)(*args)
    counts = {name: 0 for name in _COLLECTIVE_PRIMS}

    def walk(j, mult=1):
        for eqn in j.eqns:
            if eqn.primitive.name in counts:
                counts[eqn.primitive.name] += mult
            inner_mult = mult
            if eqn.primitive.name == "scan":
                inner_mult = mult * eqn.params.get("length", 1)
            for v in eqn.params.values():
                for item in v if isinstance(v, (list, tuple)) else [v]:
                    inner = getattr(item, "jaxpr", item)
                    if hasattr(inner, "eqns"):
                        walk(inner, inner_mult)

    walk(jaxpr.jaxpr)
    return counts


def shard_forward_chain(
    cfg: ModelConfig,
    chip_mesh: ChipMeshConfig,
    tokens: int = 1,
    cim: Optional[CiMConfig] = None,
    block_only: bool = False,
) -> List[ShardedPlacement]:
    """Shard the model's forward chain (``mapper.model_forward_chain``) onto
    the mesh — ``shard_model``'s own offset-bookkeeping walk, restricted to
    the chained linears the fused program can run end to end."""
    return shard_model(
        cfg, chip_mesh, tokens=tokens, cim=cim,
        matmuls=model_forward_chain(cfg, tokens, block_only=block_only),
    )


def program_eligibility(
    placements: Sequence[ShardedPlacement], chip_mesh: ChipMeshConfig
) -> List[str]:
    """Why the fused shard_map program can('t) run this chain. Empty = eligible.

    Beyond ``resolve_backend``'s per-layer conditions (devices, no
    replication fallbacks), the fusion needs the *chain* invariants: layer
    i's N is layer i+1's K; every K tile-aligns with the mesh
    (``K % (model * rows) == 0``, so the reduce-scatter hands each chip a
    whole-tile K-slice) and every N splits evenly for the tiled
    ``psum_scatter`` (``N % model == 0``).

    Example::

        >>> from repro.fabric import ChipMeshConfig, FabricConfig, map_matmul, shard_placement
        >>> fb = FabricConfig(mode="pair_sar", n_arrays=8)
        >>> cm = ChipMeshConfig(model=2, fabric=fb)
        >>> sps = [shard_placement(map_matmul("l", 4, 64, 64, fb), cm)]
        >>> program_eligibility(sps, cm)
        []
    """
    problems: List[str] = []
    if not placements:
        return ["empty layer chain"]
    fabric = chip_mesh.fabric
    n_dev = len(jax.devices())
    if n_dev < chip_mesh.n_chips:
        problems.append(
            f"host has {n_dev} jax device(s) < {chip_mesh.n_chips} chips (set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={chip_mesh.n_chips})"
        )
    prev = None
    for i, sp in enumerate(placements):
        if sp.chip_mesh != chip_mesh:
            problems.append(f"layer {i} ({sp.name}) was planned on a different mesh")
            continue
        if (sp.d_splits, sp.k_splits) != (chip_mesh.data, chip_mesh.model):
            problems.append(
                f"layer {i} ({sp.name}) has replication fallbacks: realized "
                f"{sp.d_splits}x{sp.k_splits} != mesh {chip_mesh.data}x{chip_mesh.model}"
            )
        if sp.k % (chip_mesh.model * fabric.rows) != 0:
            problems.append(
                f"layer {i} ({sp.name}) K={sp.k} is not a whole number of "
                f"{fabric.rows}-row tiles per model-axis chip"
            )
        if sp.n % chip_mesh.model != 0:
            problems.append(
                f"layer {i} ({sp.name}) N={sp.n} does not divide the model axis "
                f"({chip_mesh.model}) for the tiled psum_scatter"
            )
        if prev is not None:
            if sp.k != prev.n:
                problems.append(
                    f"chain break at layer {i}: {prev.name} outputs N={prev.n} "
                    f"but {sp.name} consumes K={sp.k}"
                )
            if sp.m != prev.m:
                problems.append(
                    f"batch mismatch at layer {i}: {prev.name} M={prev.m} vs "
                    f"{sp.name} M={sp.m}"
                )
        prev = sp
    return problems


@dataclasses.dataclass
class FabricProgram:
    """A compiled whole-model forward over the chip mesh.

    ``backend`` is the *resolved* execution path: ``"shard_map"`` runs the
    single fused SPMD program; ``"sequential"`` is the per-layer
    ``execute_sharded_matmul`` host loop (the automatic fallback, and the
    reference the fused path is tested bit-exact against on a 1x1 mesh).
    Call it like a function::

        y = program(x, weights, key=key)
        y, stats = program(x, weights, return_stats=True)

    ``weights`` is one float ``(K_i, N_i)`` matrix per chained layer
    (:attr:`weight_shapes`); quantization — per-tensor activations,
    per-column weights — matches the per-layer loop exactly.

    Example::

        >>> import jax
        >>> from repro.fabric import ChipMeshConfig, FabricConfig, compile_forward
        >>> from repro.core.cim_linear import CiMConfig
        >>> fb = FabricConfig(mode="pair_sar", n_arrays=8)
        >>> cim = CiMConfig(mode="bitplane", a_bits=4, w_bits=4, adc_bits=5, rows=16, ste=False)
        >>> prog = compile_forward(get_chain(), ChipMeshConfig(fabric=fb), cim)  # doctest: +SKIP
        >>> y = prog(x, prog.random_weights(jax.random.PRNGKey(0)))  # doctest: +SKIP
    """

    chip_mesh: ChipMeshConfig
    cim: CiMConfig
    placements: List[ShardedPlacement]
    backend: str  # resolved: "shard_map" | "sequential"
    requested_backend: str
    problems: List[str]  # why shard_map was ineligible (empty when it runs)
    _fns: dict = dataclasses.field(default_factory=dict, repr=False)

    @property
    def n_layers(self) -> int:
        return len(self.placements)

    @property
    def weight_shapes(self) -> List[Tuple[int, int]]:
        return [(sp.k, sp.n) for sp in self.placements]

    @property
    def m(self) -> int:
        return self.placements[0].m

    def random_weights(self, key: jax.Array) -> List[jnp.ndarray]:
        """Per-layer standard-normal weights of the chain's shapes
        (``fold_in(key, i)`` per layer) — for smokes, examples, tests."""
        return [
            jax.random.normal(jax.random.fold_in(key, i), (k, n))
            for i, (k, n) in enumerate(self.weight_shapes)
        ]

    def example_input(self, key: jax.Array) -> jnp.ndarray:
        """An ``(M, K0)`` input matching the planned chain shapes."""
        return jax.random.normal(key, (self.m, self.placements[0].k))

    def reference_forward(self, x, weights, key=None, backend: str = "sequential",
                          return_stats: bool = False):
        """The per-layer ``execute_sharded_matmul`` loop on this program's
        placements — what ``measure_forward`` times as the unfused baseline."""
        return per_layer_forward(
            x, weights, self.placements, self.chip_mesh, self.cim,
            key=key, backend=backend, return_stats=return_stats,
        )

    # -- fused SPMD program -------------------------------------------------

    def _fused(self, has_key: bool, collectives: bool = True):
        """Build (and cache) the jitted shard_map program.

        ``collectives=False`` compiles an identical program with every
        collective replaced by a local stand-in of the same shape —
        numerically wrong by construction, but the same per-chip compute, so
        ``t(fused) - t(local)`` isolates the collectives' wall time for
        :func:`measure_forward`.
        """
        cache_key = (has_key, collectives)
        if cache_key in self._fns:
            return self._fns[cache_key]
        cm, cim = self.chip_mesh, self.cim
        fabric = cm.fabric
        C, D = cm.model, cm.data
        cols = fabric.cols
        L = self.n_layers
        mesh = make_chip_mesh(D, C, require_concrete=True)
        qmax = (1 << (cim.a_bits - 1)) - 1 if cim.a_signed else (1 << cim.a_bits) - 1
        lo = -qmax - 1 if cim.a_signed else 0

        # qmax enters as a TRACED operand, not a literal: XLA strength-reduces
        # division by a constant into multiplication by its rounded reciprocal,
        # which would put the fused activation scale one ulp off the per-layer
        # loop's host-side quantize_symmetric and break 1x1 bit-exactness
        def chip_fn(x_blk, qmax_f, *flat):
            ws = flat[: 2 * L]
            key = flat[2 * L] if has_key else None
            di = jax.lax.axis_index("data")
            ci = jax.lax.axis_index("model")
            conversions = jnp.zeros((), jnp.int32)
            comparisons = jnp.zeros((), jnp.int32)
            h = x_blk
            for i in range(L):
                w_blk, sw_blk = ws[2 * i], ws[2 * i + 1]
                # global activation scale: max of shard maxes == global max,
                # exactly — bit-identical to the loop's quantize_symmetric
                absval = jnp.abs(h) if cim.a_signed else jnp.maximum(h, 0)
                absmax = jnp.max(absval)
                if collectives:
                    absmax = jax.lax.pmax(absmax, ("data", "model"))
                scale = jnp.where(absmax > 0, absmax / qmax_f, 1.0)
                x_int = jnp.clip(jnp.round(h / scale), lo, qmax)
                lkey = jax.random.fold_in(key, i) if has_key else None
                # K-shard index only: data chips differ via the global row
                # ids (row_offset), keeping each row's draws split-invariant
                chip_key = _chip_noise_key(lkey, ci) if has_key else None
                y_int, st = column_tile_matmul(
                    x_int, w_blk, cim, cols, key=chip_key,
                    row_offset=di * x_int.shape[0],
                )
                conversions = conversions + st.conversions
                comparisons = comparisons + st.comparisons
                if C > 1:
                    if collectives:
                        # the inter-layer combine: chip ci keeps exactly its
                        # K-slice of the NEXT layer — no gather, no re-scatter
                        y_int = jax.lax.psum_scatter(
                            y_int, "model", scatter_dimension=1, tiled=True
                        )
                    else:
                        nc = y_int.shape[1] // C
                        y_int = jax.lax.dynamic_slice_in_dim(y_int, ci * nc, nc, axis=1)
                h = y_int * scale * sw_blk
            if C > 1:
                if collectives:
                    h = jax.lax.all_gather(h, "model", axis=1, tiled=True)  # the ONE gather
                else:
                    h = jnp.concatenate([h] * C, axis=1)
            if collectives:
                conversions = jax.lax.psum(conversions, ("data", "model"))
                comparisons = jax.lax.psum(comparisons, ("data", "model"))
            return h, conversions, comparisons

        in_specs = [P("data", "model"), P()]
        for _ in range(L):
            in_specs += [P("model", None), P(None, "model")]
        if has_key:
            in_specs.append(P())
        fn = jax.jit(
            shard_map(
                chip_fn,
                mesh,
                in_specs=tuple(in_specs),
                out_specs=(P("data", None), P(), P()),
                check_rep=False,
            )
        )
        self._fns[cache_key] = fn
        return fn

    def _prepare(self, x, weights, key):
        """Flatten x, quantize weights host-side (exactly the per-layer
        loop's front-end), and assemble the fused program's argument list."""
        if len(weights) != self.n_layers:
            raise ValueError(f"expected {self.n_layers} weight matrices, got {len(weights)}")
        for i, (w, (k, n)) in enumerate(zip(weights, self.weight_shapes)):
            if tuple(w.shape) != (k, n):
                raise ValueError(
                    f"layer {i} ({self.placements[i].name}) expects weights "
                    f"({k}, {n}), got {tuple(w.shape)}"
                )
        batch_shape = x.shape[:-1]
        k0 = self.placements[0].k
        if x.shape[-1] != k0:
            raise ValueError(f"input features {x.shape[-1]} != chain K={k0}")
        xm = x.reshape(-1, k0)
        qmax = (
            (1 << (self.cim.a_bits - 1)) - 1 if self.cim.a_signed
            else (1 << self.cim.a_bits) - 1
        )
        flat = [jnp.float32(qmax)]
        for w in weights:
            w_int, sw = quantize_symmetric(w, self.cim.w_bits, self.cim.w_signed, per_axis=-1)
            flat += [w_int, sw]
        if key is not None:
            flat.append(key)
        return batch_shape, xm, flat

    def _fused_args(self, x, weights, key):
        """The fused callable's concrete argument tuple (measure_forward)."""
        _, xm, flat = self._prepare(x, weights, key)
        return (xm, *flat)

    def fused_available(self, x) -> bool:
        """Whether the fused shard_map path can run THIS input — the
        resolved backend plus ``__call__``'s ragged-batch condition
        (flattened rows divisible by the data axis), exposed so
        ``measure_forward`` never traces an infeasible shape."""
        if self.backend != "shard_map":
            return False
        return x.reshape(-1, x.shape[-1]).shape[0] % self.chip_mesh.data == 0

    def __call__(self, x, weights, key: Optional[jax.Array] = None, return_stats: bool = False):
        if self.backend != "shard_map":
            _record_request_fallback("fabric.program", self)
            _record_request("fabric.program", self, 0, fused=False)
            return per_layer_forward(
                x, weights, self.placements, self.chip_mesh, self.cim,
                key=key, backend="sequential", return_stats=return_stats,
            )
        batch_shape, xm, flat = self._prepare(x, weights, key)
        if xm.shape[0] % self.chip_mesh.data:
            if self.requested_backend == "shard_map":
                raise ValueError(
                    f"fused program unavailable: batch rows {xm.shape[0]} are "
                    f"not divisible by the data axis ({self.chip_mesh.data})"
                )
            record_fallback(
                "fabric.program", REASON_RAGGED_BATCH,
                f"batch rows {xm.shape[0]} % data axis {self.chip_mesh.data} != 0",
            )
            _record_request("fabric.program", self, 0, fused=False)
            return per_layer_forward(
                x, weights, self.placements, self.chip_mesh, self.cim,
                key=key, backend="sequential", return_stats=return_stats,
            )
        _record_request("fabric.program", self, xm.shape[0], fused=True)
        with obs_trace.span(
            "fabric.program.forward", n_layers=self.n_layers,
            mesh=f"{self.chip_mesh.data}x{self.chip_mesh.model}", m=xm.shape[0],
        ), obs_trace.annotate("fabric.program.fused"):
            y, conversions, comparisons = self._fused(key is not None)(xm, *flat)
        y = y.reshape(*batch_shape, self.placements[-1].n)
        if return_stats:
            return y, CimStats(conversions, comparisons)
        return y

    # -- introspection ------------------------------------------------------

    def collective_counts(self, x=None, weights=None, key=None) -> dict:
        """Count collective primitives in the fused program's jaxpr —
        the acceptance check that the whole forward contains at most ONE
        ``all_gather`` (and one tiled ``reduce_scatter`` per inter-layer
        combine) lives on this."""
        if self.backend != "shard_map":
            raise ValueError("collective_counts needs the shard_map backend")
        if x is None:
            x = jnp.zeros((self.m, self.placements[0].k))
        if weights is None:
            weights = [jnp.zeros(s) for s in self.weight_shapes]
        _, xm, flat = self._prepare(x, weights, key)
        return _count_collectives(self._fused(key is not None), (xm, *flat))


def compile_forward(
    model: Union[ModelConfig, Sequence[ShardedPlacement]],
    chip_mesh: ChipMeshConfig,
    cim: Optional[CiMConfig] = None,
    backend: str = "auto",
    tokens: int = 1,
    block_only: bool = False,
) -> FabricProgram:
    """Compile a whole mapped model into one fused shard_map forward.

    ``model`` is a :class:`~repro.configs.base.ModelConfig` (its forward
    chain — ``mapper.model_forward_chain`` — is sharded onto the mesh with
    the usual round-robin offsets) or an explicit list of chained
    :class:`~repro.fabric.shard.ShardedPlacement`\\ s. ``backend`` mirrors
    ``resolve_backend``: ``"shard_map"`` raises with the reasons when the
    fused program is ineligible (:func:`program_eligibility`), ``"auto"``
    falls back to the per-layer sequential loop — but unlike the per-matmul
    dispatcher, ``auto`` fuses even on a 1x1 mesh (killing per-layer Python
    dispatch is the point, one chip or many).

    Example::

        >>> import jax
        >>> from repro.core.cim_linear import CiMConfig
        >>> from repro.fabric import ChipMeshConfig, FabricConfig, compile_forward
        >>> fb = FabricConfig(mode="pair_sar", n_arrays=8)
        >>> cim = CiMConfig(mode="bitplane", a_bits=4, w_bits=4, adc_bits=5, rows=16, ste=False)
        >>> from repro.fabric import map_matmul, shard_placement
        >>> cm = ChipMeshConfig(fabric=fb)
        >>> chain = [shard_placement(map_matmul("l0", 4, 64, 64, fb, cim=cim), cm),
        ...          shard_placement(map_matmul("l1", 4, 64, 32, fb, cim=cim), cm)]
        >>> prog = compile_forward(chain, cm, cim)
        >>> x = jax.random.normal(jax.random.PRNGKey(0), (4, 64))
        >>> prog(x, prog.random_weights(jax.random.PRNGKey(1))).shape
        (4, 32)
    """
    if backend not in ("auto", "sequential", "shard_map"):
        raise ValueError(f"unknown backend {backend!r}")
    if cim is None:
        cim = CiMConfig(
            mode="bitplane", adc_bits=chip_mesh.fabric.adc_bits,
            rows=chip_mesh.fabric.rows, ste=False,
        )
    if cim.mode not in ("bitplane", "fake_quant"):
        raise ValueError(f"fabric execution needs bitplane|fake_quant, got {cim.mode!r}")
    if cim.ste:
        raise ValueError(
            "the fused forward feeds layer outputs straight into the next "
            "layer's quantizer; STE wrapping is a per-matmul training "
            "feature — pass a cim with ste=False"
        )
    if isinstance(model, ModelConfig):
        placements = shard_forward_chain(
            model, chip_mesh, tokens=tokens, cim=cim, block_only=block_only
        )
    else:
        placements = list(model)
    problems = program_eligibility(placements, chip_mesh)
    if backend == "sequential":
        resolved = "sequential"
    elif problems:
        if backend == "shard_map":
            raise ValueError("fused shard_map program unavailable: " + "; ".join(problems))
        obs_trace.event("fabric.program.ineligible", problems=list(problems))
        resolved = "sequential"
    else:
        resolved = "shard_map"
    return FabricProgram(
        chip_mesh=chip_mesh,
        cim=cim,
        placements=placements,
        backend=resolved,
        requested_backend=backend,
        problems=problems,
    )


def per_layer_forward(
    x,
    weights,
    placements: Sequence[ShardedPlacement],
    chip_mesh: ChipMeshConfig,
    cim: CiMConfig,
    key: Optional[jax.Array] = None,
    backend: str = "sequential",
    return_stats: bool = False,
):
    """The reference forward: one ``execute_sharded_matmul`` per layer, with
    the program's per-layer noise keys (``fold_in(key, i)``) — the loop the
    fused program is bit-exact against on a 1x1 mesh. Also the measured
    baseline for the per-layer gather + re-scatter + dispatch cost the
    fusion removes.

    Example::

        >>> import jax
        >>> from repro.core.cim_linear import CiMConfig
        >>> from repro.fabric import ChipMeshConfig, FabricConfig, map_matmul, shard_placement
        >>> from repro.fabric.program import per_layer_forward
        >>> fb = FabricConfig(mode="pair_sar", n_arrays=8)
        >>> cim = CiMConfig(mode="bitplane", a_bits=4, w_bits=4, adc_bits=5, rows=16, ste=False)
        >>> cm = ChipMeshConfig(fabric=fb)
        >>> sps = [shard_placement(map_matmul("l0", 4, 64, 32, fb, cim=cim), cm)]
        >>> x = jax.random.normal(jax.random.PRNGKey(0), (4, 64))
        >>> w = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
        >>> per_layer_forward(x, [w], sps, cm, cim).shape
        (4, 32)
    """
    if len(weights) != len(placements):
        raise ValueError(f"expected {len(placements)} weight matrices, got {len(weights)}")
    h = x
    conversions = jnp.zeros((), jnp.int32)
    comparisons = jnp.zeros((), jnp.int32)
    for i, (sp, w) in enumerate(zip(placements, weights)):
        lkey = jax.random.fold_in(key, i) if key is not None else None
        h, st = execute_sharded_matmul(
            h, w, chip_mesh, cim, sharded=sp, key=lkey,
            return_stats=True, backend=backend,
        )
        conversions = conversions + st.conversions
        comparisons = comparisons + st.comparisons
    if return_stats:
        return h, CimStats(conversions, comparisons)
    return h


def _time_best(fn, iters: int) -> float:
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def measure_forward(
    program,
    x=None,
    weights=None,
    key: Optional[jax.Array] = None,
    iters: int = 2,
    per_layer_backend: Optional[str] = None,
    per_layer_iters: int = 1,
    per_layer: bool = True,
) -> dict:
    """Wall-clock a fused program and isolate its collectives' time.

    ``program`` is a chain :class:`FabricProgram` or a full-block
    :class:`~repro.fabric.graph.GraphProgram` — both expose the fused /
    collective-stripped twins and a ``reference_forward`` unfused baseline.
    Runs (block-until-ready, best of ``iters`` after a warmup): the fused
    program; an identical program with the collectives replaced by local
    stand-ins of the same shapes (so the difference is the collectives'
    wall time); and the per-layer/per-node reference loop (the
    gather-per-layer baseline the fusion removes — ``per_layer_backend``
    defaults to the program's own backend, and its dispatch/trace overhead
    per call is real steady-state cost, so it is timed with
    ``per_layer_iters`` to keep smokes budgeted; ``per_layer=False`` skips
    the baseline entirely, how the CI calibration-stability re-measure
    stays cheap). The measured collective seconds land next to the modeled
    link time via ``fabric.pipeline.link_validation`` — measured
    host-simulation seconds vs modeled fabric seconds, the
    ``link_clock_calibration`` constant tracked across PRs.

    Example::

        >>> r = measure_forward(prog)  # doctest: +SKIP
        >>> sorted(r)[:3]  # doctest: +SKIP
        ['backend', 'fused_s', 'local_s']
    """
    from repro.fabric.pipeline import link_validation

    if x is None:
        x = program.example_input(jax.random.PRNGKey(0))
    if weights is None:
        weights = program.random_weights(jax.random.PRNGKey(1))

    out = {
        "backend": program.backend,
        "n_layers": program.n_layers,
        "mesh": f"{program.chip_mesh.data}x{program.chip_mesh.model}",
        "n_chips": program.chip_mesh.n_chips,
    }
    measured_collective_s = None
    # fused_available also screens ragged batches (__call__'s documented
    # fallback), which the fused twins cannot trace
    if program.backend == "shard_map" and program.fused_available(x):
        args = program._fused_args(x, weights, key)
        fused = program._fused(key is not None)
        local = program._fused(key is not None, collectives=False)
        jax.block_until_ready(fused(*args))  # compile + warm
        jax.block_until_ready(local(*args))
        out["fused_s"] = _time_best(lambda: fused(*args), iters)
        out["local_s"] = _time_best(lambda: local(*args), iters)
        measured_collective_s = max(0.0, out["fused_s"] - out["local_s"])
    if per_layer:
        loop_backend = per_layer_backend or program.backend
        out["per_layer_backend"] = loop_backend
        reference = lambda: program.reference_forward(  # noqa: E731 — timed thunk
            x, weights, key=key, backend=loop_backend
        )
        jax.block_until_ready(reference())  # warm the reference caches too
        out["per_layer_s"] = _time_best(reference, per_layer_iters)
        if "fused_s" in out:
            out["fused_speedup_vs_per_layer"] = out["per_layer_s"] / max(out["fused_s"], 1e-12)
    out.update(link_validation(program.placements, measured_collective_s))
    return out
