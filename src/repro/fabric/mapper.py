"""Map matmuls / whole models onto a CiM fabric.

One weight tile is ``rows x cols`` of the (quantized) weight matrix — exactly
one array's stored plane set. A matmul ``(M, K) @ (K, N)`` therefore shatters
into ``ceil(K/rows) * ceil(N/cols)`` tiles: K is split *across arrays* (each
array holds one reduction slice on its word lines), N across array columns,
and M streams *across time* (every input row visits each resident tile).

Tiles are assigned round-robin to the fabric's compute arrays. When a layer
(or model) has more tiles than compute arrays, arrays process their tiles in
sequential *rounds* and every tile's weights must be (re)loaded from external
memory each pass — the weight-load counts here are the paper's external
memory access (EMA) argument: an iso-area in-memory fabric holds more arrays,
so more tiles stay resident and EMA drops.

Digitization counts follow ``core.cim_linear.digitization_stats``: each
(input-plane x weight-plane) pair of each (m, k-tile, output-column) triple is
one analog-to-digital conversion.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.core.cim_linear import CiMConfig
from repro.fabric.topology import FabricConfig

__all__ = [
    "TileAssignment",
    "LayerPlacement",
    "map_matmul",
    "map_model",
    "model_matmuls",
    "model_forward_chain",
    "GraphNode",
    "ForwardGraph",
    "model_forward_graph",
    "model_block_template",
]


@dataclasses.dataclass(frozen=True)
class TileAssignment:
    """One rows x cols weight tile placed on one compute array."""

    k_tile: int
    n_tile: int
    array: int  # compute-array index on the fabric
    round: int  # sequential pass in which this array processes the tile
    k0: int
    k1: int
    n0: int
    n1: int


@dataclasses.dataclass
class LayerPlacement:
    """Placement of one matmul on the fabric, plus its cost counters.

    Example::

        >>> from repro.fabric import FabricConfig, map_matmul
        >>> p = map_matmul("l", m=4, k=64, n=64, fabric=FabricConfig(mode="pair_sar", n_arrays=8))
        >>> p.k_tiles, p.n_tiles, p.rounds, p.resident
        (4, 2, 1, True)
    """

    name: str
    m: int
    k: int
    n: int
    fabric: FabricConfig
    cim: CiMConfig
    tiles: List[TileAssignment]
    k_tiles: int
    n_tiles: int
    rounds: int

    @property
    def n_weight_tiles(self) -> int:
        return self.k_tiles * self.n_tiles

    @property
    def resident(self) -> bool:
        """All of THIS layer's tiles fit on the compute arrays at once
        (single round). Layer-local only: steady-state reload-free operation
        additionally needs the whole model resident (``fabric_report``)."""
        return self.rounds == 1

    @property
    def weight_load_bits(self) -> int:
        """External-memory bits fetched to program the tiles once."""
        return self.n_weight_tiles * self.fabric.rows * self.fabric.cols * self.cim.w_bits

    @property
    def activation_bits(self) -> int:
        """Input activation bits streamed in (each m-row visits every k-tile
        once per n-round it participates in; broadcast across an array's cols)."""
        return self.m * self.k * self.cim.a_bits

    @property
    def conversions(self) -> int:
        """Total ADC conversions (plane-pair x m x k-tile x output column)."""
        return self.cim.a_bits * self.cim.w_bits * self.m * self.k_tiles * self.n

    @property
    def conversions_per_array_max(self) -> int:
        """Conversions on the busiest compute array (sets layer latency)."""
        per_array: dict[int, int] = {}
        ab = self.cim.a_bits * self.cim.w_bits * self.m
        for t in self.tiles:
            per_array[t.array] = per_array.get(t.array, 0) + ab * (t.n1 - t.n0)
        return max(per_array.values())

    def stats(self) -> dict:
        return {
            "layer": self.name,
            "m": self.m,
            "k": self.k,
            "n": self.n,
            "tiles": self.n_weight_tiles,
            "rounds": self.rounds,
            "resident": self.resident,
            "weight_load_bits": self.weight_load_bits,
            "activation_bits": self.activation_bits,
            "conversions": self.conversions,
        }


def map_matmul(
    name: str,
    m: int,
    k: int,
    n: int,
    fabric: FabricConfig,
    cim: Optional[CiMConfig] = None,
    array_offset: int = 0,
) -> LayerPlacement:
    """Tile an (M, K) @ (K, N) matmul onto the fabric's compute arrays.

    ``array_offset`` rotates the round-robin start so consecutive layers of a
    model spread across the chip instead of piling onto array 0.

    Example::

        >>> from repro.fabric import FabricConfig, map_matmul
        >>> p = map_matmul("q_proj", m=1, k=40, n=70, fabric=FabricConfig(mode="pair_sar", n_arrays=8))
        >>> (p.k_tiles, p.n_tiles), len(p.tiles), p.rounds
        ((3, 3), 9, 2)
    """
    if cim is None:
        cim = CiMConfig(mode="bitplane", adc_bits=fabric.adc_bits, rows=fabric.rows, ste=False)
    if cim.rows != fabric.rows:
        raise ValueError(f"cim.rows={cim.rows} != fabric.rows={fabric.rows}")
    r, c = fabric.rows, fabric.cols
    k_tiles = math.ceil(k / r)
    n_tiles = math.ceil(n / c)
    n_compute = fabric.n_compute_arrays

    tiles: List[TileAssignment] = []
    idx = 0
    for nt in range(n_tiles):
        for kt in range(k_tiles):
            slot = (array_offset + idx) % n_compute
            tiles.append(
                TileAssignment(
                    k_tile=kt,
                    n_tile=nt,
                    array=slot,
                    round=idx // n_compute,
                    k0=kt * r,
                    k1=min((kt + 1) * r, k),
                    n0=nt * c,
                    n1=min((nt + 1) * c, n),
                )
            )
            idx += 1
    rounds = math.ceil(idx / n_compute)
    return LayerPlacement(
        name=name, m=m, k=k, n=n, fabric=fabric, cim=cim,
        tiles=tiles, k_tiles=k_tiles, n_tiles=n_tiles, rounds=rounds,
    )


# ---------------------------------------------------------------------------
# Model-level mapping
# ---------------------------------------------------------------------------


def model_matmuls(
    cfg: ModelConfig, tokens: int, block_only: bool = False
) -> List[Tuple[str, int, int, int]]:
    """The (name, M, K, N) linear shapes of one forward pass.

    ``block_only`` restricts to a single attention+MLP block (the
    ``examples/fabric_map.py`` workload); otherwise all ``n_layers`` layers
    plus the unembedding are included. MoE counts the ``top_k`` activated
    experts; Mamba/hybrid families map their projection matmuls.

    Example::

        >>> from repro.configs.registry import get_config
        >>> from repro.fabric import model_matmuls
        >>> [name for name, *_ in model_matmuls(get_config("smollm-135m"), 4, block_only=True)][:2]
        ['block.q_proj', 'block.k_proj']
    """
    d = cfg.d_model
    out: List[Tuple[str, int, int, int]] = []

    def attn(prefix: str):
        h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        out.append((f"{prefix}.q_proj", tokens, d, h * hd))
        out.append((f"{prefix}.k_proj", tokens, d, kv * hd))
        out.append((f"{prefix}.v_proj", tokens, d, kv * hd))
        out.append((f"{prefix}.o_proj", tokens, h * hd, d))

    def mlp(prefix: str, d_ff: int):
        out.append((f"{prefix}.gate_proj", tokens, d, d_ff))
        out.append((f"{prefix}.up_proj", tokens, d, d_ff))
        out.append((f"{prefix}.down_proj", tokens, d_ff, d))

    def moe(prefix: str):
        out.append((f"{prefix}.router", tokens, d, cfg.n_experts))
        for e in range(cfg.top_k):  # activated experts (per-token top_k)
            mlp(f"{prefix}.expert{e}", cfg.d_ff_expert)

    def mamba(prefix: str):
        di, ns, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        out.append((f"{prefix}.in_proj", tokens, d, 2 * di + 2 * ns + h))
        out.append((f"{prefix}.out_proj", tokens, di, d))

    if block_only:
        if cfg.family in ("dense", "moe", "hybrid"):
            attn("block")
        if cfg.family == "moe":
            moe("block")
        elif cfg.family == "mamba":
            mamba("block")
        else:
            mlp("block", cfg.d_ff or cfg.d_model * 4)
        return out

    for i in range(cfg.n_layers):
        p = f"layer{i}"
        if cfg.family == "dense":
            attn(p)
            mlp(p, cfg.d_ff)
        elif cfg.family == "moe":
            attn(p)
            moe(p)
        elif cfg.family == "mamba":
            mamba(p)
        elif cfg.family == "hybrid":
            mamba(p)
            if cfg.share_period and i % cfg.share_period == 0:
                attn(f"{p}.shared_attn")
                mlp(f"{p}.shared_attn", cfg.d_ff)
        else:
            raise ValueError(cfg.family)
    out.append(("unembed", tokens, d, cfg.padded_vocab))
    return out


def model_forward_chain(
    cfg: ModelConfig, tokens: int, block_only: bool = False
) -> List[Tuple[str, int, int, int]]:
    """The maximal *chained* subset of :func:`model_matmuls`: starting from
    the ``d_model`` residual stream, keep every matmul whose K equals the
    previous kept matmul's N — the linears on the forward critical path,
    where layer i's output IS layer i+1's input.

    This is the workload ``fabric.program.compile_forward`` fuses into one
    shard_map program: between chained linears the activation can stay
    K-sharded across the mesh (the elementwise/attention-mixing ops elided
    here never change the sharded layout). Sibling projections that branch
    off the residual stream rather than continue it (``k_proj`` / ``v_proj``
    / ``up_proj`` / the MoE ``router``) are skipped even when their K
    happens to match, and MoE keeps only ``expert0`` — a token's critical
    path runs through ONE activated expert; the other ``top_k - 1`` run in
    parallel, not in series. A dense transformer therefore chains
    ``q_proj -> o_proj -> gate_proj -> down_proj`` per layer plus the
    unembed; families whose residual path is not a pure matmul chain (e.g.
    Mamba's ``in_proj -> SSM -> out_proj``) yield shorter chains.

    Example::

        >>> from repro.configs.registry import get_config
        >>> from repro.fabric import model_forward_chain
        >>> [n for n, *_ in model_forward_chain(get_config("smollm-135m"), 4, block_only=True)]
        ['block.q_proj', 'block.o_proj', 'block.gate_proj', 'block.down_proj']
    """
    siblings = ("k_proj", "v_proj", "up_proj", "router")
    chain: List[Tuple[str, int, int, int]] = []
    cur = cfg.d_model
    for name, m, k, n in model_matmuls(cfg, tokens, block_only=block_only):
        parts = name.split(".")
        if parts[-1] in siblings:
            continue
        if any(p.startswith("expert") and p != "expert0" for p in parts):
            continue  # parallel experts: only one is on a token's critical path
        if k == cur:
            chain.append((name, m, k, n))
            cur = n
    return chain


# ---------------------------------------------------------------------------
# Forward graph: the complete block, siblings and mixing ops included
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GraphNode:
    """One node of a :class:`ForwardGraph`.

    ``op`` is one of:

    * ``"matmul"`` — a CiM-mapped linear ``(M, k) @ (k, n)``. ``combine``
      says how the mesh's model axis recombines the K-slice partials:
      ``"scatter"`` (tiled reduce-scatter, output stays feature-sharded) or
      ``"psum"`` (full replicated output — only the tiny MoE router, whose
      output feeds a softmax over the whole expert axis).
    * ``"norm"`` — RMS norm over the ``d``-wide feature axis (``eps``).
    * ``"attention"`` — RoPE-free causal GQA mixing ``softmax(q kᵀ) v``
      (``n_heads`` / ``n_kv_heads`` / ``head_dim``); inputs are (q, k, v).
    * ``"silu_gate"`` — ``silu(gate) * up``; inputs are (gate, up).
    * ``"residual"`` — elementwise add of its two inputs.
    * ``"moe_gate"`` — scale the expert output by the router's softmax
      probability of the one activated expert; inputs are (expert, router).

    ``inputs`` are producer-node names; the literal name ``"x"`` is the
    graph input (the embedded residual stream).
    """

    name: str
    op: str
    inputs: Tuple[str, ...]
    k: int = 0  # matmul: reduction width
    n: int = 0  # matmul: output width
    combine: str = "scatter"  # matmul: "scatter" | "psum"
    n_heads: int = 0  # attention
    n_kv_heads: int = 0  # attention
    head_dim: int = 0  # attention
    d: int = 0  # norm: feature width
    eps: float = 1e-5  # norm


@dataclasses.dataclass(frozen=True)
class ForwardGraph:
    """A complete forward pass as a node list in execution order.

    Unlike :func:`model_forward_chain` — which keeps only the residual-path
    linears and silently drops the k/v/up/router siblings plus all mixing
    ops — a graph holds EVERY matmul of the pass (sibling branches share
    their producer's input) and the non-CiM ops between them, so both the
    cost rollups and the fused executor see the model the fabric would
    actually serve.

    Example::

        >>> from repro.configs.registry import get_config
        >>> from repro.fabric import model_forward_graph
        >>> g = model_forward_graph(get_config("smollm-135m"), 4, block_only=True)
        >>> [nd.name for nd in g.matmul_nodes][:3]
        ['block.q_proj', 'block.k_proj', 'block.v_proj']
        >>> sorted({nd.op for nd in g.nodes})
        ['attention', 'matmul', 'norm', 'residual', 'silu_gate']
    """

    nodes: Tuple[GraphNode, ...]
    m: int  # tokens per pass — the M of every matmul node
    d_in: int  # graph-input feature width (d_model)
    output: str  # name of the node producing the graph output

    @property
    def matmul_nodes(self) -> Tuple[GraphNode, ...]:
        return tuple(nd for nd in self.nodes if nd.op == "matmul")

    def matmuls(self) -> List[Tuple[str, int, int, int]]:
        """The ``(name, M, K, N)`` list of every CiM linear, in node order —
        feeds ``shard_model(matmuls=...)`` exactly like ``model_matmuls``."""
        return [(nd.name, self.m, nd.k, nd.n) for nd in self.matmul_nodes]

    def node(self, name: str) -> GraphNode:
        for nd in self.nodes:
            if nd.name == name:
                return nd
        raise KeyError(name)

    def weighted_nodes(self) -> Tuple[GraphNode, ...]:
        """Nodes that carry a parameter: matmuls (a ``(k, n)`` weight) and
        norms (a ``(d,)`` scale vector) — the keys of a graph weight dict."""
        return tuple(nd for nd in self.nodes if nd.op in ("matmul", "norm"))

    def sibling_names(self) -> List[str]:
        """Matmul nodes that branch off a shared input instead of continuing
        the residual chain — exactly the placements ``model_forward_chain``
        drops (the chain-vs-graph cost delta of the report regression test)."""
        chain_suffixes = ("k_proj", "v_proj", "up_proj", "router")
        return [
            nd.name for nd in self.matmul_nodes
            if nd.name.split(".")[-1] in chain_suffixes
        ]

    def collective_budget(self, model_axis: int) -> dict:
        """The documented collective census of the fused graph program on a
        ``model_axis``-wide mesh (``GraphProgram.collective_counts`` must
        equal this — scatters are enumerated per sibling, never silently
        added):

        * one tiled ``reduce_scatter`` per scatter-combined matmul (siblings
          included: a dense block pays 7 — q/k/v/o/gate/up/down — where the
          chain paid 4);
        * ONE trailing ``all_gather``;
        * one ``pmax`` per re-quantization boundary = per *distinct* matmul
          input (siblings share their producer's quantization, so q/k/v and
          gate/up cost one boundary each);
        * one ``psum`` per norm (sum of squares over the sharded feature
          axis), per psum-combined router, plus 2 for the stats totals.

        On a 1x1-model mesh the scatters/gather vanish (nothing is sharded)
        and the boundary pmaxes/psums remain as counted no-ops.
        """
        scatter = sum(1 for nd in self.matmul_nodes if nd.combine == "scatter")
        psum_mm = sum(1 for nd in self.matmul_nodes if nd.combine == "psum")
        norms = sum(1 for nd in self.nodes if nd.op == "norm")
        boundaries = len({nd.inputs[0] for nd in self.matmul_nodes})
        many = model_axis > 1
        return {
            "reduce_scatter": scatter if many else 0,
            "all_gather": 1 if many else 0,
            "pmax": boundaries,
            "psum": norms + psum_mm + 2,
            "ppermute": 0,
            "all_to_all": 0,
        }

    def block_census(self, model_axis: int) -> dict:
        """The per-iteration collective census when THIS graph is the body
        of a scan-over-layers program (``compile_graph_forward`` with
        ``scan_layers=True``): like :meth:`collective_budget` but with no
        trailing all-gather and no stats-total psums — those happen once
        after the scan, not once per block. The scanned program's census
        must equal ``block_census x n_layers`` plus the tail graph's
        ``collective_budget`` — which is, by construction, exactly the
        unrolled full graph's ``collective_budget``.
        """
        b = self.collective_budget(model_axis)
        return {**b, "all_gather": 0, "psum": b["psum"] - 2}


def model_forward_graph(
    cfg: ModelConfig, tokens: int, block_only: bool = False
) -> ForwardGraph:
    """The COMPLETE forward pass of ``cfg`` as a :class:`ForwardGraph`.

    Supersedes :func:`model_forward_chain` as the fused-program workload:
    sibling projections (k/v/up/router) are emitted as branch outputs of the
    shared layer input instead of skipped, and the non-CiM ops between the
    linears — pre-norms, RoPE-free causal attention mixing, SiLU gating,
    residual adds, the final norm — become explicit nodes. MoE blocks route
    through ONE activated expert (``expert0``) scaled by the router's
    softmax probability; Mamba/hybrid families have no matmul-graph forward
    and raise.

    ``block_only`` emits a single ``block``-prefixed attention+MLP block
    (no final norm / unembed), mirroring ``model_matmuls(block_only=True)``.

    Example::

        >>> from repro.configs.registry import get_config
        >>> from repro.fabric import model_forward_graph
        >>> g = model_forward_graph(get_config("smollm-135m"), 4)
        >>> len(g.matmul_nodes), g.output
        (211, 'unembed')
    """
    if cfg.family not in ("dense", "moe"):
        raise ValueError(
            f"model_forward_graph supports dense|moe families; {cfg.family!r} "
            "has no pure matmul-graph forward (use model_matmuls for costs)"
        )
    d = cfg.d_model
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    nodes: List[GraphNode] = []

    def norm(name: str, src: str) -> str:
        nodes.append(GraphNode(name, "norm", (src,), d=d, eps=cfg.norm_eps))
        return name

    def mm(name: str, src: str, k: int, n: int, combine: str = "scatter") -> str:
        nodes.append(GraphNode(name, "matmul", (src,), k=k, n=n, combine=combine))
        return name

    def attn_block(p: str, resid: str) -> str:
        ln = norm(f"{p}.ln1", resid)
        q = mm(f"{p}.q_proj", ln, d, h * hd)
        kk = mm(f"{p}.k_proj", ln, d, kv * hd)
        vv = mm(f"{p}.v_proj", ln, d, kv * hd)
        nodes.append(
            GraphNode(f"{p}.attn_mix", "attention", (q, kk, vv),
                      n_heads=h, n_kv_heads=kv, head_dim=hd)
        )
        o = mm(f"{p}.o_proj", f"{p}.attn_mix", h * hd, d)
        nodes.append(GraphNode(f"{p}.attn_res", "residual", (resid, o)))
        return f"{p}.attn_res"

    def swiglu(ln: str, mm_prefix: str, d_ff: int) -> str:
        gate = mm(f"{mm_prefix}.gate_proj", ln, d, d_ff)
        up = mm(f"{mm_prefix}.up_proj", ln, d, d_ff)
        nodes.append(GraphNode(f"{mm_prefix}.silu", "silu_gate", (gate, up)))
        return mm(f"{mm_prefix}.down_proj", f"{mm_prefix}.silu", d_ff, d)

    def dense_mlp(p: str, resid: str) -> str:
        ln = norm(f"{p}.ln2", resid)
        down = swiglu(ln, p, cfg.d_ff or d * 4)
        nodes.append(GraphNode(f"{p}.mlp_res", "residual", (resid, down)))
        return f"{p}.mlp_res"

    def moe_mlp(p: str, resid: str) -> str:
        # ln2 is shared by the router and the activated expert; the router's
        # softmax needs the whole expert axis, so it recombines via psum
        ln = norm(f"{p}.ln2", resid)
        router = mm(f"{p}.router", ln, d, cfg.n_experts, combine="psum")
        down = swiglu(ln, f"{p}.expert0", cfg.d_ff_expert)
        nodes.append(GraphNode(f"{p}.moe_gate", "moe_gate", (down, router)))
        nodes.append(GraphNode(f"{p}.mlp_res", "residual", (resid, f"{p}.moe_gate")))
        return f"{p}.mlp_res"

    resid = "x"
    n_blocks = 1 if block_only else cfg.n_layers
    for i in range(n_blocks):
        p = "block" if block_only else f"layer{i}"
        resid = attn_block(p, resid)
        resid = moe_mlp(p, resid) if cfg.family == "moe" else dense_mlp(p, resid)
    if not block_only:
        resid = norm("ln_f", resid)
        resid = mm("unembed", resid, d, cfg.padded_vocab)
    return ForwardGraph(nodes=tuple(nodes), m=tokens, d_in=d, output=resid)


def model_block_template(
    cfg: ModelConfig, tokens: int
) -> Tuple[ForwardGraph, ForwardGraph]:
    """The block-template form of :func:`model_forward_graph`: ``(block,
    tail)`` where ``block`` is ONE repeated transformer block (the
    ``block.``-prefixed graph of ``block_only=True``, residual stream in,
    residual stream out) and ``tail`` holds the non-repeated nodes after the
    block stack — the final norm and the unembedding, reading the scanned
    carry as their graph input ``"x"``.

    This is the workload ``compile_graph_forward(scan_layers=True)``
    compiles: the block body traces ONCE and runs under ``jax.lax.scan``
    over weights stacked on a leading layer axis
    (``graph.stack_block_weights``), so trace/compile cost is
    depth-constant. No node precedes the first block (embeddings enter the
    graph directly as ``"x"``), so the tail is the only out-of-scan part.

    Example::

        >>> from repro.configs.registry import get_config
        >>> from repro.fabric import model_block_template
        >>> block, tail = model_block_template(get_config("smollm-135m"), 4)
        >>> block.output, [nd.name for nd in tail.nodes]
        ('block.mlp_res', ['ln_f', 'unembed'])
    """
    block = model_forward_graph(cfg, tokens, block_only=True)
    d = cfg.d_model
    tail_nodes = (
        GraphNode("ln_f", "norm", ("x",), d=d, eps=cfg.norm_eps),
        GraphNode("unembed", "matmul", ("ln_f",), k=d, n=cfg.padded_vocab),
    )
    tail = ForwardGraph(nodes=tail_nodes, m=tokens, d_in=d, output="unembed")
    return block, tail


def map_model(
    cfg: ModelConfig,
    fabric: FabricConfig,
    tokens: int = 1,
    cim: Optional[CiMConfig] = None,
    block_only: bool = False,
) -> List[LayerPlacement]:
    """Place every linear of ``cfg`` onto the fabric (round-robin across
    layers so the chip fills evenly).

    Example::

        >>> from repro.configs.registry import get_config
        >>> from repro.fabric import FabricConfig, map_model
        >>> ps = map_model(get_config("smollm-135m"), FabricConfig(mode="hybrid", n_arrays=60),
        ...                tokens=4, block_only=True)
        >>> len(ps), ps[0].name
        (7, 'block.q_proj')
    """
    placements: List[LayerPlacement] = []
    offset = 0
    for name, m, k, n in model_matmuls(cfg, tokens, block_only=block_only):
        p = map_matmul(name, m, k, n, fabric, cim=cim, array_offset=offset)
        offset = (offset + p.n_weight_tiles) % fabric.n_compute_arrays
        placements.append(p)
    return placements
