"""Map matmuls / whole models onto a CiM fabric.

One weight tile is ``rows x cols`` of the (quantized) weight matrix — exactly
one array's stored plane set. A matmul ``(M, K) @ (K, N)`` therefore shatters
into ``ceil(K/rows) * ceil(N/cols)`` tiles: K is split *across arrays* (each
array holds one reduction slice on its word lines), N across array columns,
and M streams *across time* (every input row visits each resident tile).

Tiles are assigned round-robin to the fabric's compute arrays. When a layer
(or model) has more tiles than compute arrays, arrays process their tiles in
sequential *rounds* and every tile's weights must be (re)loaded from external
memory each pass — the weight-load counts here are the paper's external
memory access (EMA) argument: an iso-area in-memory fabric holds more arrays,
so more tiles stay resident and EMA drops.

Digitization counts follow ``core.cim_linear.digitization_stats``: each
(input-plane x weight-plane) pair of each (m, k-tile, output-column) triple is
one analog-to-digital conversion.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.core.cim_linear import CiMConfig
from repro.fabric.topology import FabricConfig

__all__ = [
    "TileAssignment",
    "LayerPlacement",
    "map_matmul",
    "map_model",
    "model_matmuls",
    "model_forward_chain",
]


@dataclasses.dataclass(frozen=True)
class TileAssignment:
    """One rows x cols weight tile placed on one compute array."""

    k_tile: int
    n_tile: int
    array: int  # compute-array index on the fabric
    round: int  # sequential pass in which this array processes the tile
    k0: int
    k1: int
    n0: int
    n1: int


@dataclasses.dataclass
class LayerPlacement:
    """Placement of one matmul on the fabric, plus its cost counters.

    Example::

        >>> from repro.fabric import FabricConfig, map_matmul
        >>> p = map_matmul("l", m=4, k=64, n=64, fabric=FabricConfig(mode="pair_sar", n_arrays=8))
        >>> p.k_tiles, p.n_tiles, p.rounds, p.resident
        (4, 2, 1, True)
    """

    name: str
    m: int
    k: int
    n: int
    fabric: FabricConfig
    cim: CiMConfig
    tiles: List[TileAssignment]
    k_tiles: int
    n_tiles: int
    rounds: int

    @property
    def n_weight_tiles(self) -> int:
        return self.k_tiles * self.n_tiles

    @property
    def resident(self) -> bool:
        """All of THIS layer's tiles fit on the compute arrays at once
        (single round). Layer-local only: steady-state reload-free operation
        additionally needs the whole model resident (``fabric_report``)."""
        return self.rounds == 1

    @property
    def weight_load_bits(self) -> int:
        """External-memory bits fetched to program the tiles once."""
        return self.n_weight_tiles * self.fabric.rows * self.fabric.cols * self.cim.w_bits

    @property
    def activation_bits(self) -> int:
        """Input activation bits streamed in (each m-row visits every k-tile
        once per n-round it participates in; broadcast across an array's cols)."""
        return self.m * self.k * self.cim.a_bits

    @property
    def conversions(self) -> int:
        """Total ADC conversions (plane-pair x m x k-tile x output column)."""
        return self.cim.a_bits * self.cim.w_bits * self.m * self.k_tiles * self.n

    @property
    def conversions_per_array_max(self) -> int:
        """Conversions on the busiest compute array (sets layer latency)."""
        per_array: dict[int, int] = {}
        ab = self.cim.a_bits * self.cim.w_bits * self.m
        for t in self.tiles:
            per_array[t.array] = per_array.get(t.array, 0) + ab * (t.n1 - t.n0)
        return max(per_array.values())

    def stats(self) -> dict:
        return {
            "layer": self.name,
            "m": self.m,
            "k": self.k,
            "n": self.n,
            "tiles": self.n_weight_tiles,
            "rounds": self.rounds,
            "resident": self.resident,
            "weight_load_bits": self.weight_load_bits,
            "activation_bits": self.activation_bits,
            "conversions": self.conversions,
        }


def map_matmul(
    name: str,
    m: int,
    k: int,
    n: int,
    fabric: FabricConfig,
    cim: Optional[CiMConfig] = None,
    array_offset: int = 0,
) -> LayerPlacement:
    """Tile an (M, K) @ (K, N) matmul onto the fabric's compute arrays.

    ``array_offset`` rotates the round-robin start so consecutive layers of a
    model spread across the chip instead of piling onto array 0.

    Example::

        >>> from repro.fabric import FabricConfig, map_matmul
        >>> p = map_matmul("q_proj", m=1, k=40, n=70, fabric=FabricConfig(mode="pair_sar", n_arrays=8))
        >>> (p.k_tiles, p.n_tiles), len(p.tiles), p.rounds
        ((3, 3), 9, 2)
    """
    if cim is None:
        cim = CiMConfig(mode="bitplane", adc_bits=fabric.adc_bits, rows=fabric.rows, ste=False)
    if cim.rows != fabric.rows:
        raise ValueError(f"cim.rows={cim.rows} != fabric.rows={fabric.rows}")
    r, c = fabric.rows, fabric.cols
    k_tiles = math.ceil(k / r)
    n_tiles = math.ceil(n / c)
    n_compute = fabric.n_compute_arrays

    tiles: List[TileAssignment] = []
    idx = 0
    for nt in range(n_tiles):
        for kt in range(k_tiles):
            slot = (array_offset + idx) % n_compute
            tiles.append(
                TileAssignment(
                    k_tile=kt,
                    n_tile=nt,
                    array=slot,
                    round=idx // n_compute,
                    k0=kt * r,
                    k1=min((kt + 1) * r, k),
                    n0=nt * c,
                    n1=min((nt + 1) * c, n),
                )
            )
            idx += 1
    rounds = math.ceil(idx / n_compute)
    return LayerPlacement(
        name=name, m=m, k=k, n=n, fabric=fabric, cim=cim,
        tiles=tiles, k_tiles=k_tiles, n_tiles=n_tiles, rounds=rounds,
    )


# ---------------------------------------------------------------------------
# Model-level mapping
# ---------------------------------------------------------------------------


def model_matmuls(
    cfg: ModelConfig, tokens: int, block_only: bool = False
) -> List[Tuple[str, int, int, int]]:
    """The (name, M, K, N) linear shapes of one forward pass.

    ``block_only`` restricts to a single attention+MLP block (the
    ``examples/fabric_map.py`` workload); otherwise all ``n_layers`` layers
    plus the unembedding are included. MoE counts the ``top_k`` activated
    experts; Mamba/hybrid families map their projection matmuls.

    Example::

        >>> from repro.configs.registry import get_config
        >>> from repro.fabric import model_matmuls
        >>> [name for name, *_ in model_matmuls(get_config("smollm-135m"), 4, block_only=True)][:2]
        ['block.q_proj', 'block.k_proj']
    """
    d = cfg.d_model
    out: List[Tuple[str, int, int, int]] = []

    def attn(prefix: str):
        h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        out.append((f"{prefix}.q_proj", tokens, d, h * hd))
        out.append((f"{prefix}.k_proj", tokens, d, kv * hd))
        out.append((f"{prefix}.v_proj", tokens, d, kv * hd))
        out.append((f"{prefix}.o_proj", tokens, h * hd, d))

    def mlp(prefix: str, d_ff: int):
        out.append((f"{prefix}.gate_proj", tokens, d, d_ff))
        out.append((f"{prefix}.up_proj", tokens, d, d_ff))
        out.append((f"{prefix}.down_proj", tokens, d_ff, d))

    def moe(prefix: str):
        out.append((f"{prefix}.router", tokens, d, cfg.n_experts))
        for e in range(cfg.top_k):  # activated experts (per-token top_k)
            mlp(f"{prefix}.expert{e}", cfg.d_ff_expert)

    def mamba(prefix: str):
        di, ns, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        out.append((f"{prefix}.in_proj", tokens, d, 2 * di + 2 * ns + h))
        out.append((f"{prefix}.out_proj", tokens, di, d))

    if block_only:
        if cfg.family in ("dense", "moe", "hybrid"):
            attn("block")
        if cfg.family == "moe":
            moe("block")
        elif cfg.family == "mamba":
            mamba("block")
        else:
            mlp("block", cfg.d_ff or cfg.d_model * 4)
        return out

    for i in range(cfg.n_layers):
        p = f"layer{i}"
        if cfg.family == "dense":
            attn(p)
            mlp(p, cfg.d_ff)
        elif cfg.family == "moe":
            attn(p)
            moe(p)
        elif cfg.family == "mamba":
            mamba(p)
        elif cfg.family == "hybrid":
            mamba(p)
            if cfg.share_period and i % cfg.share_period == 0:
                attn(f"{p}.shared_attn")
                mlp(f"{p}.shared_attn", cfg.d_ff)
        else:
            raise ValueError(cfg.family)
    out.append(("unembed", tokens, d, cfg.padded_vocab))
    return out


def model_forward_chain(
    cfg: ModelConfig, tokens: int, block_only: bool = False
) -> List[Tuple[str, int, int, int]]:
    """The maximal *chained* subset of :func:`model_matmuls`: starting from
    the ``d_model`` residual stream, keep every matmul whose K equals the
    previous kept matmul's N — the linears on the forward critical path,
    where layer i's output IS layer i+1's input.

    This is the workload ``fabric.program.compile_forward`` fuses into one
    shard_map program: between chained linears the activation can stay
    K-sharded across the mesh (the elementwise/attention-mixing ops elided
    here never change the sharded layout). Sibling projections that branch
    off the residual stream rather than continue it (``k_proj`` / ``v_proj``
    / ``up_proj`` / the MoE ``router``) are skipped even when their K
    happens to match, and MoE keeps only ``expert0`` — a token's critical
    path runs through ONE activated expert; the other ``top_k - 1`` run in
    parallel, not in series. A dense transformer therefore chains
    ``q_proj -> o_proj -> gate_proj -> down_proj`` per layer plus the
    unembed; families whose residual path is not a pure matmul chain (e.g.
    Mamba's ``in_proj -> SSM -> out_proj``) yield shorter chains.

    Example::

        >>> from repro.configs.registry import get_config
        >>> from repro.fabric import model_forward_chain
        >>> [n for n, *_ in model_forward_chain(get_config("smollm-135m"), 4, block_only=True)]
        ['block.q_proj', 'block.o_proj', 'block.gate_proj', 'block.down_proj']
    """
    siblings = ("k_proj", "v_proj", "up_proj", "router")
    chain: List[Tuple[str, int, int, int]] = []
    cur = cfg.d_model
    for name, m, k, n in model_matmuls(cfg, tokens, block_only=block_only):
        parts = name.split(".")
        if parts[-1] in siblings:
            continue
        if any(p.startswith("expert") and p != "expert0" for p in parts):
            continue  # parallel experts: only one is on a token's critical path
        if k == cur:
            chain.append((name, m, k, n))
            cur = n
    return chain


def map_model(
    cfg: ModelConfig,
    fabric: FabricConfig,
    tokens: int = 1,
    cim: Optional[CiMConfig] = None,
    block_only: bool = False,
) -> List[LayerPlacement]:
    """Place every linear of ``cfg`` onto the fabric (round-robin across
    layers so the chip fills evenly).

    Example::

        >>> from repro.configs.registry import get_config
        >>> from repro.fabric import FabricConfig, map_model
        >>> ps = map_model(get_config("smollm-135m"), FabricConfig(mode="hybrid", n_arrays=60),
        ...                tokens=4, block_only=True)
        >>> len(ps), ps[0].name
        (7, 'block.q_proj')
    """
    placements: List[LayerPlacement] = []
    offset = 0
    for name, m, k, n in model_matmuls(cfg, tokens, block_only=block_only):
        p = map_matmul(name, m, k, n, fabric, cim=cim, array_offset=offset)
        offset = (offset + p.n_weight_tiles) % fabric.n_compute_arrays
        placements.append(p)
    return placements
