"""Full-transformer-block fused forward: compile a ForwardGraph into ONE
shard_map program.

``fabric.program.compile_forward`` fuses only the residual *chain*
(q -> o -> gate -> down -> unembed): the k/v/up/router siblings and every
mixing op between the linears are dropped, so the fused program is a
cost-model artifact rather than the model the paper's collaborative CiM
fabric would actually serve. This module executes the COMPLETE block stack
(``mapper.model_forward_graph``) — siblings, attention mixing, SiLU gating,
norms, residual adds — in one jitted SPMD program over the chip mesh:

  * the residual stream stays feature-sharded over the ``model`` axis the
    whole way: every scatter-combined matmul ends in a tiled
    ``psum_scatter`` whose output slice is exactly the consumer's
    tile-aligned K-slice, and ONE trailing ``all_gather`` produces the
    logits;
  * sibling branches (k/v/up) consume the SAME quantized layer input as
    their chained partner — one re-quantization boundary (a scalar ``pmax``)
    per *distinct* matmul input, not per matmul — and pay one extra
    reduce-scatter each, enumerated (never silently added) by
    ``ForwardGraph.collective_budget`` and asserted against
    ``GraphProgram.collective_counts``;
  * attention mixing runs chip-local: with ``n_heads % model == 0`` and
    ``n_kv_heads % model == 0`` the k/v scatters hand every chip whole
    GQA head groups, so ``softmax(q kᵀ) v`` (RoPE-free causal, as in
    ``models/transformer``) needs NO collective, and the chip's mixed heads
    are precisely its K-slice of ``o_proj``;
  * norms are the only ops that read across the sharded feature axis: the
    sum of squares is a per-row ``psum`` over ``model``; the MoE router —
    whose softmax needs the whole expert axis — recombines via ``psum``
    instead of a scatter and gates the ONE activated expert (``expert0``).

Numerics mirror ``fabric.program`` exactly: activation quantization uses a
TRACED ``qmax`` operand (XLA would otherwise strength-reduce the scale
division and drift one ulp), per-node ADC noise keys are
``fold_in(key, matmul_index)`` then per-chip/per-tile like every other
executor, and every matmul runs the shared ``fabric.tiles`` inner loop — so
on a 1x1 mesh the fused graph is bit-for-bit :func:`per_node_forward` (the
per-node ``execute_sharded_matmul`` + shared-mixing-helper reference loop),
noisy ADC included, and matches it on real multi-chip meshes.

:func:`transformer_graph_weights` closes the real-weights loop: it adapts
``models.transformer.init_transformer`` parameters into the graph's weight
dict, so actual model logits — not synthetic chains — run on the fabric.

``compile_graph_forward(scan_layers=True)`` is the depth-constant form:
the repeated block (``mapper.model_block_template``) traces ONCE and runs
under ``jax.lax.scan`` over weights stacked on a leading layer axis
(:func:`stack_block_weights` / :func:`unstack_block_weights`), the
embed-side norm and unembed stay outside the scan, the residual stream
stays feature-sharded across iterations, and per-layer noise keys are
derived inside the body from the traced global matmul index — so the
scanned program is still bit-for-bit the unrolled one on a 1x1 mesh while
trace+compile cost stops growing with ``n_layers``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.cim_linear import CimStats, CiMConfig, quantize_symmetric
from repro.fabric.mapper import ForwardGraph, model_block_template, model_forward_graph
from repro.fabric.shard import (
    ShardedPlacement,
    _chip_noise_key,
    execute_sharded_matmul,
    shard_model,
)
from repro.fabric.tiles import column_tile_matmul
from repro.fabric.topology import ChipMeshConfig
from repro.launch.mesh import make_chip_mesh
from repro.obs import trace as obs_trace
from repro.obs.fallback import REASON_RAGGED_BATCH, record_fallback
from repro.fabric.program import _record_request, _record_request_fallback

__all__ = [
    "GraphProgram",
    "compile_graph_forward",
    "per_node_forward",
    "graph_eligibility",
    "shard_forward_graph",
    "transformer_graph_weights",
    "stack_block_weights",
    "unstack_block_weights",
]

_NEG = -1e30


# ---------------------------------------------------------------------------
# Shared non-CiM ops — ONE definition used by the fused program and the
# per-node reference, which is what makes their bit-exactness structural
# ---------------------------------------------------------------------------


def _attention_mix(q, k, v, n_heads: int, n_kv_heads: int, head_dim: int):
    """RoPE-free causal GQA mixing ``softmax(q kᵀ / sqrt(hd)) v``.

    ``q``: (B, S, n_heads*hd); ``k``/``v``: (B, S, n_kv_heads*hd). Heads are
    independent, so the fused program calls this per chip on its head slice
    and the reference on all heads — identical per-head arithmetic.
    """
    b, s, _ = q.shape
    g = n_heads // n_kv_heads
    qh = q.reshape(b, s, n_kv_heads, g, head_dim)
    kh = k.reshape(b, s, n_kv_heads, head_dim)
    vh = v.reshape(b, s, n_kv_heads, head_dim)
    scores = jnp.einsum(
        "bqkgd,bckd->bqkgc", qh, kh, preferred_element_type=jnp.float32
    ) * (1.0 / np.sqrt(head_dim))
    pos = jnp.arange(s)
    mask = pos[None, :] <= pos[:, None]  # key c visible to query q iff c <= q
    scores = jnp.where(mask[None, :, None, None, :], scores, _NEG)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m) * mask[None, :, None, None, :].astype(jnp.float32)
    out = jnp.einsum("bqkgc,bckd->bqkgd", p, vh, preferred_element_type=jnp.float32)
    out = out / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    return out.reshape(b, s, n_heads * head_dim)


def _norm_apply(h, scale, eps: float, d_total, sumsq):
    """RMS norm given the (possibly psum-combined) sum of squares over the
    FULL feature axis; matches ``models.layers.rms_norm``'s
    ``x * rsqrt(mean(x^2) + eps) * (1 + scale)`` form.

    ``d_total`` must be a RUNTIME f32 scalar, not a Python literal: inside
    the fused jit a literal divisor gets strength-reduced to a rounded
    reciprocal (the same one-ulp drift the traced ``qmax`` guards against in
    ``fabric.program``), while the eager reference performs a true division.
    """
    inv = jax.lax.rsqrt(sumsq / d_total + eps)
    return h * inv * (1.0 + scale)


def _silu_gate(gate, up):
    return jax.nn.silu(gate) * up


def _expert0_prob(router_logits):
    """Softmax probability of the one activated expert (expert0) — the
    graph's documented MoE semantics: a token's critical path runs through
    ONE expert; the other top_k - 1 run in parallel, not in series."""
    return jax.nn.softmax(router_logits, axis=-1)[..., :1]


# ---------------------------------------------------------------------------
# Planning
# ---------------------------------------------------------------------------


def shard_forward_graph(
    cfg: ModelConfig,
    chip_mesh: ChipMeshConfig,
    tokens: int = 1,
    cim: Optional[CiMConfig] = None,
    block_only: bool = False,
) -> Tuple[ForwardGraph, List[ShardedPlacement]]:
    """Build the model's forward graph and shard every matmul node onto the
    mesh — ``shard_model``'s own offset-bookkeeping walk over the graph's
    matmul list, so graph costs and chain costs come from one planner.

    Example::

        >>> from repro.configs.registry import get_config
        >>> from repro.fabric import ChipMeshConfig, FabricConfig, shard_forward_graph
        >>> cm = ChipMeshConfig(fabric=FabricConfig(mode="hybrid", n_arrays=60))
        >>> g, sps = shard_forward_graph(get_config("smollm-135m"), cm, tokens=4,
        ...                              block_only=True)
        >>> len(sps) == len(g.matmul_nodes)
        True
    """
    graph = model_forward_graph(cfg, tokens, block_only=block_only)
    placements = shard_model(
        cfg, chip_mesh, tokens=tokens, cim=cim, matmuls=graph.matmuls()
    )
    return graph, placements


def graph_eligibility(
    graph: ForwardGraph,
    placements: Sequence[ShardedPlacement],
    chip_mesh: ChipMeshConfig,
) -> List[str]:
    """Why the fused graph program can('t) run. Empty = eligible.

    Beyond the per-matmul conditions of ``program_eligibility`` (devices,
    no replication fallbacks, ``K % (model * rows) == 0``, ``N % model``
    for scatter-combined nodes), the graph needs the mixing invariants:
    attention heads must divide the model axis (``n_heads % model == 0``
    and ``n_kv_heads % model == 0``) so the k/v scatters hand every chip
    whole GQA head groups and mixing stays chip-local.

    Example::

        >>> from repro.configs.registry import get_config
        >>> from repro.fabric import ChipMeshConfig, FabricConfig, shard_forward_graph
        >>> from repro.fabric.graph import graph_eligibility
        >>> cm = ChipMeshConfig(fabric=FabricConfig(mode="hybrid", n_arrays=60))
        >>> g, sps = shard_forward_graph(get_config("smollm-135m"), cm, tokens=4,
        ...                              block_only=True)
        >>> graph_eligibility(g, sps, cm)
        []
    """
    problems: List[str] = []
    mm_nodes = graph.matmul_nodes
    if not mm_nodes:
        return ["empty graph"]
    fabric = chip_mesh.fabric
    C = chip_mesh.model
    n_dev = len(jax.devices())
    if n_dev < chip_mesh.n_chips:
        problems.append(
            f"host has {n_dev} jax device(s) < {chip_mesh.n_chips} chips (set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={chip_mesh.n_chips})"
        )
    if len(placements) != len(mm_nodes):
        return problems + [
            f"graph has {len(mm_nodes)} matmul nodes but {len(placements)} "
            "placements were supplied"
        ]
    for node, sp in zip(mm_nodes, placements):
        if (sp.name, sp.k, sp.n) != (node.name, node.k, node.n):
            problems.append(
                f"placement {sp.name} (K={sp.k}, N={sp.n}) does not match "
                f"graph node {node.name} (K={node.k}, N={node.n})"
            )
            continue
        if sp.chip_mesh != chip_mesh:
            problems.append(f"{sp.name} was planned on a different mesh")
            continue
        if (sp.d_splits, sp.k_splits) != (chip_mesh.data, chip_mesh.model):
            problems.append(
                f"{sp.name} has replication fallbacks: realized "
                f"{sp.d_splits}x{sp.k_splits} != mesh {chip_mesh.data}x{chip_mesh.model}"
            )
        if sp.k % (C * fabric.rows) != 0:
            problems.append(
                f"{sp.name} K={sp.k} is not a whole number of "
                f"{fabric.rows}-row tiles per model-axis chip"
            )
        if node.combine == "scatter" and sp.n % C != 0:
            problems.append(
                f"{sp.name} N={sp.n} does not divide the model axis ({C}) "
                "for the tiled psum_scatter"
            )
    for node in graph.nodes:
        if node.op == "attention":
            if node.n_heads % C or node.n_kv_heads % C:
                problems.append(
                    f"{node.name}: heads {node.n_heads}/{node.n_kv_heads} (q/kv) "
                    f"do not divide the model axis ({C}); chip-local GQA mixing "
                    "needs whole head groups per chip"
                )
    return problems


# ---------------------------------------------------------------------------
# The fused program
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class GraphProgram:
    """A compiled full-block forward graph over the chip mesh.

    Call it like a function on ``(B, S, d_model)`` embeddings::

        y = program(x, weights, key=key)           # (B, S, N_out)
        y, stats = program(x, weights, return_stats=True)

    ``weights`` is a dict keyed by node name: one float ``(K, N)`` matrix
    per matmul node and one ``(d,)`` scale vector per norm node
    (:meth:`weight_shapes`; :func:`transformer_graph_weights` builds it from
    real ``init_transformer`` params, :meth:`random_weights` from a key).
    ``backend`` is the resolved path: ``"shard_map"`` runs the single fused
    SPMD program, ``"sequential"`` the per-node reference loop
    (:func:`per_node_forward`) — also the automatic fallback when the
    runtime batch does not divide the data axis (the documented ragged-batch
    path).

    Example::

        >>> import jax
        >>> from repro.fabric import ChipMeshConfig, FabricConfig, compile_graph_forward
        >>> prog = compile_graph_forward(cfg, ChipMeshConfig(fabric=fb), cim)  # doctest: +SKIP
        >>> y = prog(x, prog.random_weights(jax.random.PRNGKey(0)))  # doctest: +SKIP
    """

    graph: ForwardGraph
    chip_mesh: ChipMeshConfig
    cim: CiMConfig
    placements: List[ShardedPlacement]  # aligned with graph.matmul_nodes
    backend: str  # resolved: "shard_map" | "sequential"
    requested_backend: str
    problems: List[str]  # why shard_map was ineligible (empty when it runs)
    # scan-over-layers form (compile_graph_forward(scan_layers=True)): the
    # repeated block traces ONCE and runs under lax.scan over weights stacked
    # on a leading layer axis; block_graph/tail_graph are the
    # mapper.model_block_template pair and n_blocks the scan trip count.
    # graph/placements still describe the full unrolled model (budget,
    # reports, reference loop); only the traced program changes shape.
    scan_layers: bool = False
    block_graph: Optional[ForwardGraph] = None
    tail_graph: Optional[ForwardGraph] = None
    n_blocks: int = 0
    _fns: dict = dataclasses.field(default_factory=dict, repr=False)

    @property
    def n_layers(self) -> int:
        """Matmul-node count (the unit measure_forward reports)."""
        return len(self.placements)

    @property
    def m(self) -> int:
        return self.graph.m

    @property
    def d_in(self) -> int:
        return self.graph.d_in

    @property
    def n_out(self) -> int:
        out = self.graph.node(self.graph.output)
        return out.n if out.op == "matmul" else self.graph.d_in

    def weight_shapes(self) -> Dict[str, Tuple[int, ...]]:
        """Expected shape per weighted node: ``(K, N)`` for matmuls,
        ``(d,)`` for norm scales. The scanned form instead keys the repeated
        block's weights once under the ``block.`` prefix with a leading
        ``n_blocks`` layer axis (``stack_block_weights`` builds that dict
        from real ``init_transformer`` params)."""
        shapes: Dict[str, Tuple[int, ...]] = {}
        if self.scan_layers:
            L = self.n_blocks
            for nd in self.block_graph.weighted_nodes():
                shapes[nd.name] = (
                    (L, nd.k, nd.n) if nd.op == "matmul" else (L, nd.d)
                )
            for nd in self.tail_graph.weighted_nodes():
                shapes[nd.name] = (nd.k, nd.n) if nd.op == "matmul" else (nd.d,)
            return shapes
        for nd in self.graph.weighted_nodes():
            shapes[nd.name] = (nd.k, nd.n) if nd.op == "matmul" else (nd.d,)
        return shapes

    def random_weights(self, key: jax.Array) -> Dict[str, jnp.ndarray]:
        """Standard-normal matmul weights and 0.1-scaled norm scales
        (``fold_in(key, i)`` per weighted node) — for smokes and tests. The
        scanned form stacks the SAME per-layer draws on the leading layer
        axis, so one key yields corresponding weights in both forms."""
        out: Dict[str, jnp.ndarray] = {}
        for i, nd in enumerate(self.graph.weighted_nodes()):
            k = jax.random.fold_in(key, i)
            if nd.op == "matmul":
                out[nd.name] = jax.random.normal(k, (nd.k, nd.n))
            else:
                out[nd.name] = 0.1 * jax.random.normal(k, (nd.d,))
        if self.scan_layers:
            return _stack_layer_weights(out, self.n_blocks)
        return out

    def example_input(self, key: jax.Array) -> jnp.ndarray:
        """A ``(B, S, d)`` input matching the planned token count ``m`` —
        batch set to the data axis when it divides (the fused-eligible
        shape), else a single sequence."""
        b = self.chip_mesh.data if self.m % self.chip_mesh.data == 0 else 1
        return jax.random.normal(key, (b, self.m // b, self.d_in))

    # -- fused SPMD program -------------------------------------------------

    def _fused(self, has_key: bool, collectives: bool = True):
        """Build (and cache) the jitted shard_map graph program.

        ``collectives=False`` compiles the timing twin: every collective is
        replaced by a local stand-in of the same shape (numerically wrong by
        construction, same per-chip compute) so ``t(fused) - t(local)``
        isolates the collectives' wall time for ``measure_forward``.
        """
        cache_key = (has_key, collectives)
        if cache_key in self._fns:
            return self._fns[cache_key]
        cm, cim, graph = self.chip_mesh, self.cim, self.graph
        fabric = cm.fabric
        C, D = cm.model, cm.data
        cols = fabric.cols
        mesh = make_chip_mesh(D, C, require_concrete=True)
        qmax = (1 << (cim.a_bits - 1)) - 1 if cim.a_signed else (1 << cim.a_bits) - 1
        lo = -qmax - 1 if cim.a_signed else 0
        scan = self.scan_layers
        if scan:
            block, tail = self.block_graph, self.tail_graph
            block_weighted = block.weighted_nodes()
            tail_weighted = tail.weighted_nodes()
            mm_per_block = len(block.matmul_nodes)
            n_blocks = self.n_blocks
        else:
            weighted = graph.weighted_nodes()

        def parse_params(nodes_weighted, args):
            """flat args -> {name: (w_int, sw) | scale}; returns args used."""
            params, i = {}, 0
            for nd in nodes_weighted:
                if nd.op == "matmul":
                    params[nd.name] = (args[i], args[i + 1])  # (w_int, sw)
                    i += 2
                else:
                    params[nd.name] = args[i]
                    i += 1
            return params, i

        # qmax is a TRACED operand for the same reason as fabric.program: a
        # literal divisor gets strength-reduced to a rounded reciprocal,
        # putting the fused activation scale one ulp off the reference's
        # host-side quantize_symmetric. one_f is a traced 1.0 that guards
        # the graph's other eager-vs-jit seam: whole-program fusion lets
        # LLVM contract `residual + (y_int*scale*sw)` into a single-rounding
        # FMA (optimization_barrier is stripped before fusion on CPU).
        # Multiplying each add-feeding node output by the runtime one_f
        # leaves only `fma(y, 1, residual) == round(y + residual)` — the
        # eager reference's exact arithmetic. Both guards survive the scan
        # body unchanged: qmax_f/one_f stay traced operands closed over by
        # the body, so XLA cannot specialize on them per iteration either.
        # mask_blk is this data-shard's (b_loc, 1, 1) slice of the pad-row
        # mask: 1.0 on real rows, 0.0 on bucket padding. Multiplying it into
        # every matmul node output keeps pad rows at exactly zero through the
        # whole block stack — a noisy ADC turns a zero input row into nonzero
        # codes (the half-LSB mav bias sits inside comparator sigma), which
        # would otherwise leak into the GLOBAL absmax at the next
        # re-quantization boundary and perturb real rows' scales. For real
        # rows `y * 1.0` is bitwise identity, and a fused
        # `fma(y, 1.0, residual) == round(y + residual)` — the same guard
        # argument as one_f, so unpadded results are untouched.
        def chip_fn(x_blk, qmax_f, one_f, mask_blk, *flat):
            key = flat[-1] if has_key else None
            di = jax.lax.axis_index("data")
            ci = jax.lax.axis_index("model")
            b_loc, s = x_blk.shape[0], x_blk.shape[1]

            def run_nodes(nodes, vals, params, mm_idx0, conversions, comparisons):
                """ONE interpreter for a node list — the unrolled program,
                the scanned block body, and the out-of-scan tail all execute
                through it, which is what keeps their semantics identical.
                ``mm_idx0`` offsets the per-node noise keys so the scanned
                body reproduces the unrolled program's global
                ``fold_in(key, matmul_index)`` derivation exactly (it is a
                traced ``layer * mm_per_block`` inside the scan)."""
                qcache = {}  # input-node name -> (x_int 2d, scale): one
                # re-quantization boundary per DISTINCT matmul input, so
                # sibling branches share their producer's quantization
                mm_idx = 0
                for node in nodes:
                    if node.op == "matmul":
                        src = node.inputs[0]
                        if src not in qcache:
                            h = vals[src]
                            absval = jnp.abs(h) if cim.a_signed else jnp.maximum(h, 0)
                            absmax = jnp.max(absval)
                            if collectives:
                                # max of shard maxes IS the global max, exactly
                                absmax = jax.lax.pmax(absmax, ("data", "model"))
                            scale = jnp.where(absmax > 0, absmax / qmax_f, 1.0)
                            x_int = jnp.clip(jnp.round(h / scale), lo, qmax)
                            qcache[src] = (x_int.reshape(-1, x_int.shape[-1]), scale)
                        x_int2, scale = qcache[src]
                        w_blk, sw_blk = params[node.name]
                        nkey = (
                            jax.random.fold_in(key, mm_idx0 + mm_idx)
                            if has_key else None
                        )
                        # K-shard index only: data chips are distinguished by
                        # the global row ids (row_offset), so each row's noise
                        # draws are invariant to the batch size and data split
                        chip_key = _chip_noise_key(nkey, ci) if has_key else None
                        y_int, st = column_tile_matmul(
                            x_int2, w_blk, cim, cols, key=chip_key,
                            row_offset=di * x_int2.shape[0],
                        )
                        conversions = conversions + st.conversions
                        comparisons = comparisons + st.comparisons
                        if node.combine == "scatter":
                            if C > 1:
                                if collectives:
                                    # the combine that leaves chip ci holding its
                                    # tile-aligned K-slice of the consumer
                                    y_int = jax.lax.psum_scatter(
                                        y_int, "model", scatter_dimension=1, tiled=True
                                    )
                                else:
                                    nc = y_int.shape[1] // C
                                    y_int = jax.lax.dynamic_slice_in_dim(
                                        y_int, ci * nc, nc, axis=1
                                    )
                        else:  # psum: the router's full replicated output
                            if collectives:
                                y_int = jax.lax.psum(y_int, "model")
                        y = y_int * scale * sw_blk * one_f  # one_f: no FMA across
                        # the CiM boundary; mask_blk re-zeroes pad rows the
                        # noisy ADC lifted off zero (see chip_fn comment)
                        vals[node.name] = y.reshape(b_loc, s, -1) * mask_blk
                        mm_idx += 1
                    elif node.op == "norm":
                        h = vals[node.inputs[0]]
                        sumsq = jnp.sum(h * h, axis=-1, keepdims=True)
                        if collectives:
                            sumsq = jax.lax.psum(sumsq, "model")
                        vals[node.name] = _norm_apply(
                            h, params[node.name], node.eps, node.d * one_f, sumsq
                        )
                    elif node.op == "attention":
                        q, k_, v_ = (vals[nm] for nm in node.inputs)
                        vals[node.name] = _attention_mix(
                            q, k_, v_, node.n_heads // C, node.n_kv_heads // C,
                            node.head_dim,
                        )
                    elif node.op == "silu_gate":
                        vals[node.name] = _silu_gate(*(vals[nm] for nm in node.inputs))
                    elif node.op == "residual":
                        a, b = (vals[nm] for nm in node.inputs)
                        vals[node.name] = a + b
                    elif node.op == "moe_gate":
                        expert, router = (vals[nm] for nm in node.inputs)
                        # one_f: the gated product feeds a residual add — see above
                        vals[node.name] = expert * _expert0_prob(router) * one_f
                    else:  # pragma: no cover — taxonomy is closed in the mapper
                        raise ValueError(f"unknown graph op {node.op!r}")
                return vals, conversions, comparisons

            conversions = jnp.zeros((), jnp.int32)
            comparisons = jnp.zeros((), jnp.int32)
            if scan:
                stacked, used = parse_params(block_weighted, flat)
                tail_params, _ = parse_params(tail_weighted, flat[used:])

                def body(carry, xs):
                    h, conv, comp = carry
                    li, params_l = xs  # scan slices the leading layer axis
                    vals, conv, comp = run_nodes(
                        block.nodes, {"x": h}, params_l,
                        li * mm_per_block, conv, comp,
                    )
                    # the carry stays the feature-sharded residual stream:
                    # the block body never gathers, so iteration i+1 reads
                    # exactly the K-slice layout iteration i produced
                    return (vals[block.output], conv, comp), None

                (h, conversions, comparisons), _ = jax.lax.scan(
                    body,
                    (x_blk, conversions, comparisons),
                    (jnp.arange(n_blocks, dtype=jnp.int32), stacked),
                )
                vals, conversions, comparisons = run_nodes(
                    tail.nodes, {"x": h}, tail_params,
                    n_blocks * mm_per_block, conversions, comparisons,
                )
                out = vals[tail.output]
            else:
                params, _ = parse_params(weighted, flat)
                vals, conversions, comparisons = run_nodes(
                    graph.nodes, {"x": x_blk}, params, 0, conversions, comparisons
                )
                out = vals[graph.output]
            if C > 1:
                if collectives:
                    out = jax.lax.all_gather(out, "model", axis=2, tiled=True)
                else:
                    out = jnp.concatenate([out] * C, axis=2)
            if collectives:
                conversions = jax.lax.psum(conversions, ("data", "model"))
                comparisons = jax.lax.psum(comparisons, ("data", "model"))
            return out, conversions, comparisons

        in_specs: List = [P("data", None, "model"), P(), P(), P("data", None, None)]
        if scan:
            # stacked block weights: leading layer axis unsharded, the rest
            # sharded exactly like the unrolled per-layer specs
            for nd in block_weighted:
                if nd.op == "matmul":
                    in_specs.append(P(None, "model", None))
                    in_specs.append(
                        P(None, None, "model") if nd.combine == "scatter"
                        else P(None, None, None)
                    )
                else:
                    in_specs.append(P(None, "model"))
            tail_spec_nodes = tail_weighted
        else:
            tail_spec_nodes = weighted
        for nd in tail_spec_nodes:
            if nd.op == "matmul":
                in_specs.append(P("model", None))
                in_specs.append(
                    P(None, "model") if nd.combine == "scatter" else P(None, None)
                )
            else:
                in_specs.append(P("model"))
        if has_key:
            in_specs.append(P())
        fn = jax.jit(
            shard_map(
                chip_fn,
                mesh,
                in_specs=tuple(in_specs),
                out_specs=(P("data", None, None), P(), P()),
                check_rep=False,
            )
        )
        self._fns[cache_key] = fn
        return fn

    def _prepare(self, x, weights, key, real_rows=None):
        """Validate shapes, quantize matmul weights host-side (exactly the
        reference loop's front-end), and assemble the fused argument list.

        ``real_rows`` marks the first ``real_rows`` batch rows as real and the
        rest as bucket padding (``fabric.autotune``): the pad-row mask operand
        zeroes padded rows at every matmul node so they cannot perturb the
        global quantization scales real rows see."""
        shapes = self.weight_shapes()
        missing = sorted(set(shapes) - set(weights))
        if missing:
            raise ValueError(f"missing graph weights: {missing}")
        if x.ndim != 3:
            raise ValueError(
                f"graph forward wants (batch, seq, d) embeddings; got {x.shape}"
            )
        if x.shape[-1] != self.d_in:
            raise ValueError(f"input features {x.shape[-1]} != graph d={self.d_in}")
        for name, shape in shapes.items():
            if tuple(weights[name].shape) != shape:
                raise ValueError(
                    f"node {name} expects weights {shape}, got "
                    f"{tuple(weights[name].shape)}"
                )
        qmax = (
            (1 << (self.cim.a_bits - 1)) - 1 if self.cim.a_signed
            else (1 << self.cim.a_bits) - 1
        )
        if real_rows is None:
            mask = jnp.ones((x.shape[0], 1, 1), jnp.float32)
        else:
            if not 1 <= real_rows <= x.shape[0]:
                raise ValueError(
                    f"real_rows={real_rows} outside [1, batch={x.shape[0]}]"
                )
            mask = (
                (jnp.arange(x.shape[0]) < real_rows)
                .astype(jnp.float32)
                .reshape(-1, 1, 1)
            )
        flat = [jnp.float32(qmax), jnp.float32(1.0), mask]
        if self.scan_layers:
            for nd in self.block_graph.weighted_nodes():
                w = weights[nd.name]
                if nd.op == "matmul":
                    # per-layer host-side quantization in a Python loop, NOT
                    # a vmap: each w[i] goes through the EXACT same
                    # quantize_symmetric call the unrolled program makes, so
                    # the scan body's sliced (w_int, sw) are bit-identical
                    per = [
                        quantize_symmetric(
                            w[i], self.cim.w_bits, self.cim.w_signed, per_axis=-1
                        )
                        for i in range(self.n_blocks)
                    ]
                    flat += [
                        jnp.stack([p[0] for p in per]),
                        jnp.stack([p[1] for p in per]),
                    ]
                else:
                    flat.append(jnp.asarray(w, jnp.float32))
            spec_nodes = self.tail_graph.weighted_nodes()
        else:
            spec_nodes = self.graph.weighted_nodes()
        for nd in spec_nodes:
            if nd.op == "matmul":
                w_int, sw = quantize_symmetric(
                    weights[nd.name], self.cim.w_bits, self.cim.w_signed, per_axis=-1
                )
                flat += [w_int, sw]
            else:
                flat.append(jnp.asarray(weights[nd.name], jnp.float32))
        if key is not None:
            flat.append(key)
        return flat

    def _unrolled_weights(self, weights):
        """The per-layer weight dict the reference loop wants — stacked
        ``block.`` weights unstacked back to ``layer{i}.`` keys when this is
        a scanned program, passthrough otherwise."""
        if self.scan_layers:
            return unstack_block_weights(weights, self.n_blocks)
        return weights

    def _fused_args(self, x, weights, key, real_rows=None):
        """The fused callable's concrete argument tuple (measure_forward)."""
        return (x, *self._prepare(x, weights, key, real_rows=real_rows))

    def fused_available(self, x) -> bool:
        """Whether the fused shard_map path can run THIS input — the
        resolved backend plus ``__call__``'s ragged-batch condition (batch
        divisible by the data axis), exposed so ``measure_forward`` never
        traces an infeasible shape."""
        if self.backend != "shard_map" or x.ndim != 3:
            return False
        return x.shape[0] % self.chip_mesh.data == 0

    def __call__(self, x, weights, key: Optional[jax.Array] = None,
                 return_stats: bool = False, real_rows: Optional[int] = None):
        """Run the program. ``real_rows`` (``fabric.autotune``'s bucketed
        batches) declares that only the first ``real_rows`` batch rows are
        real and the rest are zero padding up to a bucket boundary: the fused
        program masks pad rows out of every matmul node, the returned logits
        are sliced back to ``real_rows``, and stats/metrics/EMA account only
        the real rows — so a padded run is bit-exact to, and reports exactly
        like, the unpadded reference."""
        b = x.shape[0]
        if real_rows is not None and not 1 <= real_rows <= b:
            raise ValueError(f"real_rows={real_rows} outside [1, batch={b}]")
        if self.backend != "shard_map" or b % self.chip_mesh.data:
            if self.backend == "shard_map":
                # fused program exists but THIS batch is ragged
                if self.requested_backend == "shard_map":
                    raise ValueError(
                        f"fused graph program unavailable: batch {b} is "
                        f"not divisible by the data axis ({self.chip_mesh.data})"
                    )
                # the documented ragged-batch path: fall back to the per-node
                # reference loop (bit-identical semantics, host dispatch)
                record_fallback(
                    "fabric.graph", REASON_RAGGED_BATCH,
                    f"batch {b} % data axis {self.chip_mesh.data} != 0",
                )
            else:
                _record_request_fallback("fabric.graph", self)
            _record_request("fabric.graph", self, 0, fused=False)
            # pad rows are pure bucket filler — the reference loop only ever
            # sees the real rows (per-row noise keys make that equivalent)
            x_ref = x if real_rows is None else x[:real_rows]
            return per_node_forward(
                x_ref, self._unrolled_weights(weights), self.graph,
                self.placements, self.chip_mesh, self.cim,
                key=key, backend="sequential", return_stats=return_stats,
            )
        flat = self._prepare(x, weights, key, real_rows=real_rows)
        rows = b if real_rows is None else real_rows
        _record_request("fabric.graph", self, rows * x.shape[1], fused=True)
        with obs_trace.span(
            "fabric.graph.forward", n_matmuls=self.n_layers,
            mesh=f"{self.chip_mesh.data}x{self.chip_mesh.model}",
            tokens=rows * x.shape[1],
        ), obs_trace.annotate("fabric.graph.fused"):
            y, conversions, comparisons = self._fused(key is not None)(x, *flat)
        if real_rows is not None:
            y = y[:real_rows]
            # conversions are per-row-constant (planes x k-tiles x columns
            # per row), so real_rows/b rescaling is exact; comparator counts
            # are data-dependent, so the pad-row share is removed
            # proportionally (pad rows digitize all-zero mavs)
            conversions = conversions * real_rows // b
            comparisons = comparisons * real_rows // b
        if return_stats:
            return y, CimStats(conversions, comparisons)
        return y

    def reference_forward(self, x, weights, key=None, backend: str = "sequential",
                          return_stats: bool = False):
        """The per-node reference loop on this program's placements — what
        ``measure_forward`` times as the unfused baseline. Accepts this
        program's own weight dict, stacked or not (scanned weights are
        unstacked back to ``layer{i}.`` keys first)."""
        return per_node_forward(
            x, self._unrolled_weights(weights), self.graph, self.placements,
            self.chip_mesh, self.cim,
            key=key, backend=backend, return_stats=return_stats,
        )

    # -- introspection ------------------------------------------------------

    def collective_counts(self, x=None, weights=None, key=None) -> dict:
        """Count collective primitives in the fused jaxpr — asserted equal
        to ``graph.collective_budget(model)``: per-sibling scatters are
        enumerated, ONE trailing all-gather, one pmax per re-quantization
        boundary, one psum per norm/router plus the two stats totals.

        The scanned form counts identically: the jaxpr walk multiplies
        collectives inside a ``scan`` body by its trip count, so one traced
        block reports per-block census × ``n_blocks`` — the same link
        traffic the unrolled program enumerates eqn by eqn. Tracing is
        ``jax.make_jaxpr`` only (no XLA compile), so this is cheap at any
        depth."""
        from repro.fabric.program import _count_collectives

        if self.backend != "shard_map":
            raise ValueError("collective_counts needs the shard_map backend")
        if x is None:
            b = self.chip_mesh.data
            x = jnp.zeros((b, max(1, self.m // b), self.d_in))
        if weights is None:
            weights = {
                name: jnp.zeros(shape) for name, shape in self.weight_shapes().items()
            }
        flat = self._prepare(x, weights, key)
        return _count_collectives(self._fused(key is not None), (x, *flat))

    def collective_budget(self) -> dict:
        """The documented budget (``ForwardGraph.collective_budget``) for
        this program's mesh."""
        return self.graph.collective_budget(self.chip_mesh.model)


def compile_graph_forward(
    model: Union[ModelConfig, ForwardGraph],
    chip_mesh: ChipMeshConfig,
    cim: Optional[CiMConfig] = None,
    backend: str = "auto",
    tokens: int = 1,
    block_only: bool = False,
    placements: Optional[Sequence[ShardedPlacement]] = None,
    scan_layers: bool = False,
) -> GraphProgram:
    """Compile a complete transformer-block stack into one fused shard_map
    forward over the chip mesh.

    ``model`` is a :class:`~repro.configs.base.ModelConfig` (its forward
    graph — ``mapper.model_forward_graph`` — is built and sharded with the
    usual round-robin offsets) or an explicit :class:`ForwardGraph` (with
    optional pre-sharded ``placements``). ``backend`` mirrors
    ``compile_forward``: ``"shard_map"`` raises with the reasons when the
    fused program is ineligible (:func:`graph_eligibility`), ``"auto"``
    falls back to the per-node loop — and fuses even on a 1x1 mesh, where
    killing the per-node Python dispatch is the point.

    ``scan_layers=True`` compiles the repeated transformer block ONCE and
    runs it under ``jax.lax.scan`` over weights stacked on a leading layer
    axis (``stack_block_weights`` builds that dict from real params;
    :meth:`GraphProgram.random_weights` stacks its own draws). Trace and
    compile cost become depth-constant while the logits stay bit-for-bit
    equal to the unrolled program on a 1x1 mesh, noisy ADC included — the
    per-layer noise keys are ``fold_in``-derived from a traced global
    matmul index inside the body, and the traced-qmax/traced-1.0 guards
    are closed over by the scan body unchanged. Requires a ``ModelConfig``
    (the block template comes from ``mapper.model_block_template``) and
    the full model (``block_only=False``).

    Example::

        >>> import jax
        >>> from repro.core.cim_linear import CiMConfig
        >>> from repro.fabric import ChipMeshConfig, FabricConfig, compile_graph_forward
        >>> from repro.configs.base import ModelConfig
        >>> cfg = ModelConfig(name="toy", family="dense", n_layers=1, d_model=64,
        ...                   vocab=64, n_heads=4, n_kv_heads=2, head_dim=16,
        ...                   d_ff=128, pad_vocab_multiple=16)
        >>> fb = FabricConfig(mode="pair_sar", n_arrays=8)
        >>> cim = CiMConfig(mode="bitplane", a_bits=4, w_bits=4, adc_bits=5, rows=16, ste=False)
        >>> prog = compile_graph_forward(cfg, ChipMeshConfig(fabric=fb), cim, tokens=4)
        >>> x = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 64))
        >>> prog(x, prog.random_weights(jax.random.PRNGKey(1))).shape
        (1, 4, 64)
    """
    if backend not in ("auto", "sequential", "shard_map"):
        raise ValueError(f"unknown backend {backend!r}")
    if scan_layers:
        if not isinstance(model, ModelConfig):
            raise ValueError(
                "scan_layers needs a ModelConfig: the repeated-block template "
                "comes from mapper.model_block_template, not an ad-hoc graph"
            )
        if block_only:
            raise ValueError(
                "scan_layers compiles the FULL model (the scan runs the "
                "block n_layers times); drop block_only"
            )
    if cim is None:
        cim = CiMConfig(
            mode="bitplane", adc_bits=chip_mesh.fabric.adc_bits,
            rows=chip_mesh.fabric.rows, ste=False,
        )
    if cim.mode not in ("bitplane", "fake_quant"):
        raise ValueError(f"fabric execution needs bitplane|fake_quant, got {cim.mode!r}")
    if cim.ste:
        raise ValueError(
            "the fused graph feeds node outputs straight into the next "
            "CiM boundary's quantizer; pass a cim with ste=False"
        )
    if isinstance(model, ModelConfig):
        graph, placements = shard_forward_graph(
            model, chip_mesh, tokens=tokens, cim=cim, block_only=block_only
        )
    else:
        graph = model
        if placements is None:
            placements = shard_model(
                None, chip_mesh, tokens=graph.m, cim=cim, matmuls=graph.matmuls()
            )
        else:
            placements = list(placements)
    problems = graph_eligibility(graph, placements, chip_mesh)
    if backend == "sequential":
        resolved = "sequential"
    elif problems:
        if backend == "shard_map":
            raise ValueError("fused graph program unavailable: " + "; ".join(problems))
        obs_trace.event("fabric.graph.ineligible", problems=list(problems))
        resolved = "sequential"
    else:
        resolved = "shard_map"
    block_graph = tail_graph = None
    n_blocks = 0
    if scan_layers:
        block_graph, tail_graph = model_block_template(model, tokens)
        n_blocks = model.n_layers
    return GraphProgram(
        graph=graph,
        chip_mesh=chip_mesh,
        cim=cim,
        placements=list(placements),
        backend=resolved,
        requested_backend=backend,
        problems=problems,
        scan_layers=scan_layers,
        block_graph=block_graph,
        tail_graph=tail_graph,
        n_blocks=n_blocks,
    )


def per_node_forward(
    x,
    weights: Dict[str, jnp.ndarray],
    graph: ForwardGraph,
    placements: Sequence[ShardedPlacement],
    chip_mesh: ChipMeshConfig,
    cim: CiMConfig,
    key: Optional[jax.Array] = None,
    backend: str = "sequential",
    return_stats: bool = False,
    key_fn=None,
):
    """The reference forward: one ``execute_sharded_matmul`` per matmul node
    plus the SAME shared mixing helpers as the fused program, with the
    program's per-node noise keys (``fold_in(key, matmul_index)``) — the
    loop the fused graph is bit-exact against on a 1x1 mesh, and the
    documented fallback for ragged batches.

    ``key_fn(key, matmul_index) -> node_key`` overrides the default
    derivation — the noise-key-independence tests use it to prove the
    scanned program would diverge if layers shared keys.

    Example::

        >>> import jax
        >>> from repro.core.cim_linear import CiMConfig
        >>> from repro.fabric import ChipMeshConfig, FabricConfig, compile_graph_forward
        >>> from repro.fabric.graph import per_node_forward
        >>> from repro.configs.base import ModelConfig
        >>> cfg = ModelConfig(name="toy", family="dense", n_layers=1, d_model=64,
        ...                   vocab=64, n_heads=4, n_kv_heads=2, head_dim=16,
        ...                   d_ff=128, pad_vocab_multiple=16)
        >>> fb = FabricConfig(mode="pair_sar", n_arrays=8)
        >>> cim = CiMConfig(mode="bitplane", a_bits=4, w_bits=4, adc_bits=5, rows=16, ste=False)
        >>> prog = compile_graph_forward(cfg, ChipMeshConfig(fabric=fb), cim, tokens=4)
        >>> x = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 64))
        >>> ws = prog.random_weights(jax.random.PRNGKey(1))
        >>> per_node_forward(x, ws, prog.graph, prog.placements,
        ...                  prog.chip_mesh, cim).shape
        (1, 4, 64)
    """
    if x.ndim != 3:
        raise ValueError(f"graph forward wants (batch, seq, d) embeddings; got {x.shape}")
    sp_by_name = {sp.name: sp for sp in placements}
    b, s = x.shape[0], x.shape[1]
    conversions = jnp.zeros((), jnp.int32)
    comparisons = jnp.zeros((), jnp.int32)
    vals = {"x": x}
    mm_idx = 0
    for node in graph.nodes:
        if node.op == "matmul":
            h = vals[node.inputs[0]]
            if key is None:
                nkey = None
            elif key_fn is not None:
                nkey = key_fn(key, mm_idx)
            else:
                nkey = jax.random.fold_in(key, mm_idx)
            y2, st = execute_sharded_matmul(
                h.reshape(-1, h.shape[-1]), weights[node.name], chip_mesh, cim,
                sharded=sp_by_name[node.name], key=nkey, return_stats=True,
                backend=backend,
            )
            conversions = conversions + st.conversions
            comparisons = comparisons + st.comparisons
            vals[node.name] = y2.reshape(b, s, -1)
            mm_idx += 1
        elif node.op == "norm":
            h = vals[node.inputs[0]]
            sumsq = jnp.sum(h * h, axis=-1, keepdims=True)
            vals[node.name] = _norm_apply(
                h, jnp.asarray(weights[node.name], jnp.float32), node.eps,
                jnp.float32(node.d), sumsq,
            )
        elif node.op == "attention":
            q, k_, v_ = (vals[nm] for nm in node.inputs)
            vals[node.name] = _attention_mix(
                q, k_, v_, node.n_heads, node.n_kv_heads, node.head_dim
            )
        elif node.op == "silu_gate":
            vals[node.name] = _silu_gate(*(vals[nm] for nm in node.inputs))
        elif node.op == "residual":
            a, b_ = (vals[nm] for nm in node.inputs)
            vals[node.name] = a + b_
        elif node.op == "moe_gate":
            expert, router = (vals[nm] for nm in node.inputs)
            vals[node.name] = expert * _expert0_prob(router)
        else:  # pragma: no cover
            raise ValueError(f"unknown graph op {node.op!r}")
    out = vals[graph.output]
    if return_stats:
        return out, CimStats(conversions, comparisons)
    return out


def transformer_graph_weights(
    params: dict, cfg: ModelConfig, block_only: bool = False
) -> Dict[str, jnp.ndarray]:
    """Adapt real ``models.transformer.init_transformer`` parameters into a
    graph weight dict — the end-to-end real-weights path.

    Matmul weights are cast to float32 (the fabric quantizes them itself,
    per column); norm scales map ``ln1``/``ln2``/``ln_f`` directly. MoE maps
    the router plus the ONE activated expert's (expert0) SwiGLU weights, per
    the graph's documented MoE semantics. ``block_only`` uses layer 0 under
    the ``block`` prefix. QKV biases are not representable on the fabric
    (the mapper places pure matmuls) and raise.

    Example::

        >>> import jax
        >>> from repro.configs.base import ModelConfig
        >>> from repro.models.transformer import init_transformer
        >>> from repro.fabric.graph import transformer_graph_weights
        >>> cfg = ModelConfig(name="toy", family="dense", n_layers=2, d_model=64,
        ...                   vocab=64, n_heads=4, n_kv_heads=2, head_dim=16,
        ...                   d_ff=128, pad_vocab_multiple=16, param_dtype="float32")
        >>> ws = transformer_graph_weights(init_transformer(jax.random.PRNGKey(0), cfg), cfg)
        >>> ws["layer0.q_proj"].shape, ws["ln_f"].shape, ws["unembed"].shape
        ((64, 64), (64,), (64, 64))
    """
    if cfg.qkv_bias:
        raise ValueError("the fabric graph maps pure matmuls; qkv_bias is unsupported")
    if cfg.family not in ("dense", "moe"):
        raise ValueError(f"no transformer graph for family {cfg.family!r}")
    f32 = lambda a: jnp.asarray(a, jnp.float32)  # noqa: E731
    out: Dict[str, jnp.ndarray] = {}
    attn = params["attn"]
    for i in range(1 if block_only else cfg.n_layers):
        p = "block" if block_only else f"layer{i}"
        out[f"{p}.ln1"] = f32(params["ln1"][i])
        out[f"{p}.q_proj"] = f32(attn["wq"][i])
        out[f"{p}.k_proj"] = f32(attn["wk"][i])
        out[f"{p}.v_proj"] = f32(attn["wv"][i])
        out[f"{p}.o_proj"] = f32(attn["wo"][i])
        out[f"{p}.ln2"] = f32(params["ln2"][i])
        if cfg.n_experts:
            moe = params["moe"]
            out[f"{p}.router"] = f32(moe["router"][i])
            out[f"{p}.expert0.gate_proj"] = f32(moe["w_gate"][i, 0])
            out[f"{p}.expert0.up_proj"] = f32(moe["w_up"][i, 0])
            out[f"{p}.expert0.down_proj"] = f32(moe["w_down"][i, 0])
        else:
            mlp = params["mlp"]
            out[f"{p}.gate_proj"] = f32(mlp["w_gate"][i])
            out[f"{p}.up_proj"] = f32(mlp["w_up"][i])
            out[f"{p}.down_proj"] = f32(mlp["w_down"][i])
    if not block_only:
        from repro.models.layers import unembed_weight

        out["ln_f"] = f32(params["ln_f"])
        out["unembed"] = f32(unembed_weight(params["embed"], cfg))
    return out


def stack_block_weights(params: dict, cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    """Adapt real ``init_transformer`` parameters into the SCANNED graph
    weight dict: the repeated block's weights keyed once under the
    ``block.`` prefix with their native leading ``(n_layers, ...)`` axis —
    ``init_transformer`` already stacks every per-layer parameter, so this
    is a relabelling, not a copy — plus the out-of-scan tail (``ln_f``,
    ``unembed``). Slicing layer ``i`` off any stacked entry reproduces
    ``transformer_graph_weights``'s ``layer{i}.*`` entry exactly.

    Same representability rules as :func:`transformer_graph_weights`:
    pure matmuls only (``qkv_bias`` raises), dense or 1-activated-expert
    MoE (``expert0``).

    Example::

        >>> import jax
        >>> from repro.configs.base import ModelConfig
        >>> from repro.models.transformer import init_transformer
        >>> from repro.fabric.graph import stack_block_weights
        >>> cfg = ModelConfig(name="toy", family="dense", n_layers=2, d_model=64,
        ...                   vocab=64, n_heads=4, n_kv_heads=2, head_dim=16,
        ...                   d_ff=128, pad_vocab_multiple=16, param_dtype="float32")
        >>> ws = stack_block_weights(init_transformer(jax.random.PRNGKey(0), cfg), cfg)
        >>> ws["block.q_proj"].shape, ws["block.ln1"].shape, ws["unembed"].shape
        ((2, 64, 64), (2, 64), (64, 64))
    """
    if cfg.qkv_bias:
        raise ValueError("the fabric graph maps pure matmuls; qkv_bias is unsupported")
    if cfg.family not in ("dense", "moe"):
        raise ValueError(f"no transformer graph for family {cfg.family!r}")
    from repro.models.layers import unembed_weight

    f32 = lambda a: jnp.asarray(a, jnp.float32)  # noqa: E731
    attn = params["attn"]
    out: Dict[str, jnp.ndarray] = {
        "block.ln1": f32(params["ln1"]),
        "block.q_proj": f32(attn["wq"]),
        "block.k_proj": f32(attn["wk"]),
        "block.v_proj": f32(attn["wv"]),
        "block.o_proj": f32(attn["wo"]),
        "block.ln2": f32(params["ln2"]),
    }
    if cfg.n_experts:
        moe = params["moe"]
        out["block.router"] = f32(moe["router"])
        out["block.expert0.gate_proj"] = f32(moe["w_gate"][:, 0])
        out["block.expert0.up_proj"] = f32(moe["w_up"][:, 0])
        out["block.expert0.down_proj"] = f32(moe["w_down"][:, 0])
    else:
        mlp = params["mlp"]
        out["block.gate_proj"] = f32(mlp["w_gate"])
        out["block.up_proj"] = f32(mlp["w_up"])
        out["block.down_proj"] = f32(mlp["w_down"])
    out["ln_f"] = f32(params["ln_f"])
    out["unembed"] = f32(unembed_weight(params["embed"], cfg))
    return out


def unstack_block_weights(
    weights: Dict[str, jnp.ndarray], n_layers: int
) -> Dict[str, jnp.ndarray]:
    """The inverse adapter: a scanned (``block.``-stacked) weight dict back
    to the unrolled ``layer{i}.*`` form — each layer is a zero-copy slice
    of the stacked array, so the per-node reference loop sees exactly the
    weights the scan body would slice at iteration ``i``.

    Example::

        >>> import jax.numpy as jnp
        >>> from repro.fabric.graph import unstack_block_weights
        >>> ws = unstack_block_weights(
        ...     {"block.ln1": jnp.zeros((2, 4)), "ln_f": jnp.ones(4)}, 2)
        >>> sorted(ws)
        ['layer0.ln1', 'layer1.ln1', 'ln_f']
    """
    out: Dict[str, jnp.ndarray] = {}
    for name, w in weights.items():
        if name.startswith("block."):
            suffix = name[len("block."):]
            for i in range(n_layers):
                out[f"layer{i}.{suffix}"] = w[i]
        else:
            out[name] = w
    return out


def _stack_layer_weights(
    weights: Dict[str, jnp.ndarray], n_layers: int
) -> Dict[str, jnp.ndarray]:
    """Stack an unrolled ``layer{i}.*`` weight dict onto the leading layer
    axis under the ``block.`` prefix (random_weights' scanned form)."""
    out: Dict[str, jnp.ndarray] = {}
    done = set()
    for name in weights:
        if name.startswith("layer") and "." in name:
            suffix = name.split(".", 1)[1]
            if suffix in done:
                continue
            done.add(suffix)
            out[f"block.{suffix}"] = jnp.stack(
                [weights[f"layer{i}.{suffix}"] for i in range(n_layers)]
            )
        else:
            out[name] = weights[name]
    return out
