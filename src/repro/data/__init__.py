"""Data pipelines: deterministic synthetic LM tokens + procedural MNIST."""

from repro.data.mnist_synth import load_mnist_synth
from repro.data.tokens import TokenPipeline

__all__ = ["TokenPipeline", "load_mnist_synth"]
