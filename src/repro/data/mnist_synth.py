"""Procedural synthetic MNIST (offline container — no downloads).

Digits 0–9 rendered from a classic 5×7 bitmap font, upscaled to 16×16, then
augmented with per-sample random shifts (±2 px), pixel dropout, and Gaussian
noise. Deterministic per (seed, split). An MLP reaches >95% accuracy — the
regime of the paper's Fig. 7(c,d) MNIST experiment; the *trend* of accuracy
vs ADC operating point is the reproduction target (DESIGN.md §9).
"""

from __future__ import annotations

import numpy as np

__all__ = ["load_mnist_synth", "IMG_DIM"]

IMG_DIM = 16 * 16

# 5x7 hex font, digits 0-9 (column-major bits, classic ROM font)
_FONT = {
    0: ["01110", "10001", "10011", "10101", "11001", "10001", "01110"],
    1: ["00100", "01100", "00100", "00100", "00100", "00100", "01110"],
    2: ["01110", "10001", "00001", "00010", "00100", "01000", "11111"],
    3: ["11111", "00010", "00100", "00010", "00001", "10001", "01110"],
    4: ["00010", "00110", "01010", "10010", "11111", "00010", "00010"],
    5: ["11111", "10000", "11110", "00001", "00001", "10001", "01110"],
    6: ["00110", "01000", "10000", "11110", "10001", "10001", "01110"],
    7: ["11111", "00001", "00010", "00100", "01000", "01000", "01000"],
    8: ["01110", "10001", "10001", "01110", "10001", "10001", "01110"],
    9: ["01110", "10001", "10001", "01111", "00001", "00010", "01100"],
}


def _glyph16(digit: int) -> np.ndarray:
    g = np.array([[int(c) for c in row] for row in _FONT[digit]], np.float32)
    # upscale 5x7 -> 10x14, then pad to 16x16 centered
    g = np.repeat(np.repeat(g, 2, axis=0), 2, axis=1)  # 14x10
    out = np.zeros((16, 16), np.float32)
    out[1:15, 3:13] = g
    return out


def load_mnist_synth(n_train: int = 8192, n_test: int = 2048, seed: int = 0):
    """Returns (x_train, y_train, x_test, y_test); x flattened to 256, in [0,1]."""
    glyphs = np.stack([_glyph16(d) for d in range(10)])

    def make(n, rng):
        y = rng.integers(0, 10, n)
        x = glyphs[y].copy()
        # random shift ±2 px
        sx = rng.integers(-2, 3, n)
        sy = rng.integers(-2, 3, n)
        for i in range(n):
            x[i] = np.roll(np.roll(x[i], sy[i], axis=0), sx[i], axis=1)
        # pixel dropout + noise + contrast jitter
        drop = rng.random(x.shape) < 0.05
        x = np.where(drop, 0.0, x)
        x = x * rng.uniform(0.7, 1.0, (n, 1, 1))
        x = x + 0.15 * rng.standard_normal(x.shape)
        return np.clip(x, 0, 1).reshape(n, -1).astype(np.float32), y.astype(np.int32)

    rng = np.random.default_rng(seed)
    x_tr, y_tr = make(n_train, rng)
    x_te, y_te = make(n_test, np.random.default_rng(seed + 1))
    return x_tr, y_tr, x_te, y_te
