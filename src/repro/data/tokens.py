"""Deterministic synthetic LM token pipeline.

Sequences follow a learnable affine-chain structure: with probability
``p_struct`` the next token is ``(a·prev + b) mod vocab``, else uniform
random. A model that learns the chain reaches xent ≈ -(p·log p) ·…· well
below log(vocab), so training-loss *decrease* is a meaningful signal.

Deterministic per (seed, step, dp_rank): seekable for checkpoint/restart —
restoring step k reproduces exactly the batch stream a non-failed run would
have seen (fault-tolerance requirement).
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["TokenPipeline"]


@dataclasses.dataclass(frozen=True)
class TokenPipeline:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    p_struct: float = 0.8
    a: int = 7
    b: int = 3

    def batch(self, step: int, dp_rank: int = 0, dp_size: int = 1) -> dict:
        """Batch shard for one data-parallel rank at one step (numpy)."""
        assert self.global_batch % dp_size == 0
        local = self.global_batch // dp_size
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, dp_rank])
        )
        toks = np.empty((local, self.seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, local)
        structured = rng.random((local, self.seq_len)) < self.p_struct
        noise = rng.integers(0, self.vocab, (local, self.seq_len))
        for t in range(self.seq_len):
            chain = (self.a * toks[:, t] + self.b) % self.vocab
            toks[:, t + 1] = np.where(structured[:, t], chain, noise[:, t])
        return {"inputs": toks[:, :-1], "labels": toks[:, 1:]}
