"""Metrics registry: counters, gauges, histograms for the fabric stack.

Collection is *contextvar-scoped* like ``obs.trace`` and
``launch.shardings.record_fallbacks``: instrumented code calls the
module-level helpers (:func:`inc`, :func:`set_gauge`, :func:`observe`),
which are no-ops unless a :func:`collecting` block is active — so the
fabric layers carry their instrumentation unconditionally and pay only a
ContextVar read when nobody is listening. All recorded values are host
Python numbers (placement-analytic counts, wall-clock seconds); traced
jax values never enter the registry, which is what keeps metrics
provably neutral to compiled programs.

The canonical metric names the fabric layers emit are tabulated in
``docs/observability.md``; the CI obs smoke
(``tools/ci_check.py`` -> ``BENCH_obs.json``) gates on their presence.
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "collecting",
    "active",
    "inc",
    "set_gauge",
    "observe",
    "get_value",
]

# Stack of active registries (innermost last), concurrency-safe like the
# sharding fallback recorders.
_REGISTRIES: contextvars.ContextVar[tuple] = contextvars.ContextVar(
    "obs_registries", default=()
)

# Seconds-oriented default buckets: fabric latencies span sub-us modeled
# link times to multi-second host-simulation loops.
DEFAULT_BUCKETS = (1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1.0, 10.0, float("inf"))

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: dict) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _label_str(key: LabelKey) -> str:
    if not key:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in key) + "}"


class Counter:
    """A monotonically increasing labeled counter.

    Example::

        >>> from repro.obs import MetricsRegistry
        >>> c = MetricsRegistry().counter("fabric_requests_total")
        >>> c.inc(path="fused"); c.inc(2, path="fallback")
        >>> c.value(path="fused"), c.value(path="fallback")
        (1.0, 2.0)
    """

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.samples: Dict[LabelKey, float] = {}

    def inc(self, value: float = 1.0, **labels) -> None:
        if value < 0:
            raise ValueError(f"counter {self.name} cannot decrease (inc {value})")
        key = _label_key(labels)
        self.samples[key] = self.samples.get(key, 0.0) + value

    def value(self, **labels) -> float:
        return self.samples.get(_label_key(labels), 0.0)


class Gauge:
    """A labeled gauge (set to the latest observation).

    Example::

        >>> from repro.obs import MetricsRegistry
        >>> g = MetricsRegistry().gauge("fabric_link_clock_calibration")
        >>> g.set(2.96e4)
        >>> g.value()
        29600.0
    """

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.samples: Dict[LabelKey, float] = {}

    def set(self, value: float, **labels) -> None:
        self.samples[_label_key(labels)] = float(value)

    def value(self, **labels) -> float:
        return self.samples.get(_label_key(labels), 0.0)


class Histogram:
    """A labeled cumulative-bucket histogram (Prometheus semantics:
    each ``le`` bucket counts observations <= its bound).

    Example::

        >>> from repro.obs import MetricsRegistry
        >>> h = MetricsRegistry().histogram("lat_seconds", buckets=(0.1, 1.0, float("inf")))
        >>> h.observe(0.05); h.observe(0.5)
        >>> h.count(), h.sum()
        (2, 0.55)
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = "", buckets: Sequence[float] = DEFAULT_BUCKETS):
        self.name = name
        self.help = help
        self.buckets = tuple(buckets)
        if self.buckets[-1] != float("inf"):
            self.buckets = self.buckets + (float("inf"),)
        # label key -> (per-bucket counts, sum, count)
        self.samples: Dict[LabelKey, list] = {}

    def observe(self, value: float, **labels) -> None:
        key = _label_key(labels)
        if key not in self.samples:
            self.samples[key] = [[0] * len(self.buckets), 0.0, 0]
        counts, _, _ = self.samples[key]
        for i, bound in enumerate(self.buckets):
            if value <= bound:
                counts[i] += 1
        self.samples[key][1] += float(value)
        self.samples[key][2] += 1

    def count(self, **labels) -> int:
        s = self.samples.get(_label_key(labels))
        return s[2] if s else 0

    def sum(self, **labels) -> float:
        s = self.samples.get(_label_key(labels))
        return s[1] if s else 0.0


class MetricsRegistry:
    """Get-or-create home for every metric of one :func:`collecting` block.

    Example::

        >>> from repro.obs import MetricsRegistry
        >>> reg = MetricsRegistry()
        >>> reg.counter("fabric_requests_total").inc(path="fused")
        >>> sorted(reg.names())
        ['fabric_requests_total']
        >>> "fabric_requests_total" in reg.prometheus_text()
        True
    """

    def __init__(self):
        self._metrics: Dict[str, object] = {}

    def _get(self, cls, name: str, help: str, **kwargs):
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, help=help, **kwargs)
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise ValueError(
                f"metric {name!r} already registered as {m.kind}, not {cls.kind}"
            )
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def snapshot(self) -> dict:
        """JSON-ready dump: metric name -> kind + labeled samples."""
        out = {}
        for name, m in sorted(self._metrics.items()):
            if m.kind == "histogram":
                out[name] = {
                    "kind": m.kind,
                    "samples": [
                        {"labels": dict(k), "count": s[2], "sum": s[1]}
                        for k, s in sorted(m.samples.items())
                    ],
                }
            else:
                out[name] = {
                    "kind": m.kind,
                    "samples": [
                        {"labels": dict(k), "value": v}
                        for k, v in sorted(m.samples.items())
                    ],
                }
        return out

    def prometheus_text(self) -> str:
        """The Prometheus text exposition of every registered metric."""
        lines: List[str] = []
        for name, m in sorted(self._metrics.items()):
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            if m.kind == "histogram":
                for key, (counts, total, count) in sorted(m.samples.items()):
                    for bound, c in zip(m.buckets, counts):
                        le = "+Inf" if bound == float("inf") else repr(bound)
                        labels = _label_str(key + (("le", le),))
                        lines.append(f"{name}_bucket{labels} {c}")
                    lines.append(f"{name}_sum{_label_str(key)} {total}")
                    lines.append(f"{name}_count{_label_str(key)} {count}")
            else:
                for key, v in sorted(m.samples.items()):
                    val = int(v) if float(v).is_integer() else v
                    lines.append(f"{name}{_label_str(key)} {val}")
        return "\n".join(lines) + ("\n" if lines else "")


@contextlib.contextmanager
def collecting(registry: Optional[MetricsRegistry] = None) -> Iterator[MetricsRegistry]:
    """Scope metric collection to a block.

    Every module-level :func:`inc` / :func:`set_gauge` / :func:`observe`
    inside the block lands on the yielded registry (and on enclosing
    registries — nesting composes). Outside any block the helpers are
    no-ops.

    Example::

        >>> from repro.obs import collecting, inc
        >>> with collecting() as reg:
        ...     inc("fabric_requests_total", path="fused")
        >>> reg.counter("fabric_requests_total").value(path="fused")
        1.0
    """
    reg = registry if registry is not None else MetricsRegistry()
    token = _REGISTRIES.set(_REGISTRIES.get() + (reg,))
    try:
        yield reg
    finally:
        _REGISTRIES.reset(token)


def active() -> bool:
    """Whether any :func:`collecting` block is active in this context.

    Example::

        >>> from repro.obs import active, collecting
        >>> active()
        False
        >>> with collecting():
        ...     active()
        True
    """
    return bool(_REGISTRIES.get())


def inc(name: str, value: float = 1.0, help: str = "", **labels) -> None:
    """Increment counter ``name`` on every active registry (no-op when
    collection is disabled).

    Example::

        >>> from repro.obs import collecting, inc
        >>> inc("noop_total")  # no registry: silently dropped
        >>> with collecting() as reg:
        ...     inc("fabric_fallback_total", reason="ragged_batch")
        >>> reg.counter("fabric_fallback_total").value(reason="ragged_batch")
        1.0
    """
    for reg in _REGISTRIES.get():
        reg.counter(name, help=help).inc(value, **labels)


def set_gauge(name: str, value: float, help: str = "", **labels) -> None:
    """Set gauge ``name`` on every active registry (no-op when disabled).

    Example::

        >>> from repro.obs import collecting, set_gauge
        >>> with collecting() as reg:
        ...     set_gauge("fabric_link_clock_calibration", 2.9e4)
        >>> reg.gauge("fabric_link_clock_calibration").value()
        29000.0
    """
    for reg in _REGISTRIES.get():
        reg.gauge(name, help=help).set(value, **labels)


def observe(name: str, value: float, help: str = "", **labels) -> None:
    """Record ``value`` into histogram ``name`` on every active registry
    (no-op when disabled).

    Example::

        >>> from repro.obs import collecting, observe
        >>> with collecting() as reg:
        ...     observe("serve_prefill_seconds", 0.12)
        >>> reg.histogram("serve_prefill_seconds").count()
        1
    """
    for reg in _REGISTRIES.get():
        reg.histogram(name, help=help).observe(value, **labels)


def get_value(name: str, **labels) -> float:
    """Read counter/gauge ``name`` from the innermost active registry
    (0.0 when disabled or unregistered) — how the serve summary line
    reads back the counters the fabric layers just incremented.

    Example::

        >>> from repro.obs import collecting, get_value, inc
        >>> with collecting():
        ...     inc("fabric_requests_total", path="fused")
        ...     get_value("fabric_requests_total", path="fused")
        1.0
    """
    regs = _REGISTRIES.get()
    if not regs:
        return 0.0
    m = regs[-1]._metrics.get(name)
    if m is None or m.kind == "histogram":
        return 0.0
    return m.value(**labels)
