"""repro.obs — observability for the fabric serving stack.

Telemetry in three coordinated pieces, all contextvar-scoped and all
zero-cost when no observer is active:

  * :mod:`repro.obs.trace` — wall-clock spans + point events
    (``tracing`` / ``span`` / ``event`` / ``annotate``). Host-side only;
    enabling tracing provably does not change compiled programs.
  * :mod:`repro.obs.metrics` — counters / gauges / histograms
    (``collecting`` / ``inc`` / ``set_gauge`` / ``observe``) with
    Prometheus text exposition.
  * :mod:`repro.obs.sinks` — JSONL event log and Prometheus scrape-file
    writers (``JsonlSink`` / ``read_jsonl`` / ``write_prometheus``).

:mod:`repro.obs.fallback` pins the canonical fallback-reason taxonomy
(``ragged_batch``, ``insufficient_devices``, ...) that the fabric layers
emit through :func:`record_fallback`.

See ``docs/observability.md`` for the metric-name table, sink formats,
and the ``link_clock_calibration`` semantics.
"""

from repro.obs.fallback import (
    FALLBACK_REASONS,
    REASON_INELIGIBLE,
    REASON_INSUFFICIENT_DEVICES,
    REASON_NO_BUCKET,
    REASON_RAGGED_BATCH,
    REASON_REPLICATION_FALLBACK,
    REASON_REQUESTED_SEQUENTIAL,
    classify_fallback,
    record_fallback,
)
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    active,
    collecting,
    get_value,
    inc,
    observe,
    set_gauge,
)
from repro.obs.sinks import JsonlSink, read_jsonl, write_prometheus
from repro.obs.trace import Tracer, annotate, enabled, event, span, tracing

__all__ = [
    # trace
    "Tracer",
    "tracing",
    "span",
    "event",
    "enabled",
    "annotate",
    # metrics
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "collecting",
    "active",
    "inc",
    "set_gauge",
    "observe",
    "get_value",
    # sinks
    "JsonlSink",
    "read_jsonl",
    "write_prometheus",
    # fallback taxonomy
    "REASON_RAGGED_BATCH",
    "REASON_INSUFFICIENT_DEVICES",
    "REASON_REPLICATION_FALLBACK",
    "REASON_REQUESTED_SEQUENTIAL",
    "REASON_INELIGIBLE",
    "REASON_NO_BUCKET",
    "FALLBACK_REASONS",
    "classify_fallback",
    "record_fallback",
]
