"""Telemetry sinks: JSONL event log + Prometheus-style text exposition.

Two on-disk formats, both append/overwrite-atomic at the record level:

  * **JSONL** — one JSON object per line, written the moment a span/event
    finishes (:class:`JsonlSink`, fed by ``obs.trace.tracing(jsonl=...)``).
    :func:`read_jsonl` is the parse-clean loader the CI obs smoke gates on.
  * **Prometheus text exposition** — ``# HELP`` / ``# TYPE`` headers plus
    one ``name{label="v"} value`` sample line per labeled series, the
    format any Prometheus-compatible scraper ingests
    (:func:`write_prometheus`, built on
    ``MetricsRegistry.prometheus_text``).
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import List, Optional

__all__ = ["JsonlSink", "read_jsonl", "write_prometheus"]


class JsonlSink:
    """Append telemetry records to a file, one JSON object per line.

    The file is opened lazily on the first :meth:`write` and flushed per
    record, so a crashed serve process still leaves a parseable log of
    everything that finished. Non-JSON-serializable attribute values are
    stringified rather than raised on — a telemetry sink must never take
    the serving path down.

    Example::

        >>> import tempfile, os
        >>> path = os.path.join(tempfile.mkdtemp(), "obs.jsonl")
        >>> sink = JsonlSink(path)
        >>> sink.write({"kind": "event", "name": "demo"})
        >>> sink.close()
        >>> read_jsonl(path)[0]["name"]
        'demo'
    """

    def __init__(self, path):
        self.path = Path(path)
        self._fh = None

    def write(self, record: dict) -> None:
        if self._fh is None:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            self._fh = self.path.open("a")
        self._fh.write(json.dumps(record, default=str) + "\n")
        self._fh.flush()

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None


def read_jsonl(path) -> List[dict]:
    """Load a JSONL telemetry log, raising on any unparseable line —
    the strictness the CI obs smoke relies on ("JSONL parse-clean").

    Example::

        >>> import tempfile, os
        >>> path = os.path.join(tempfile.mkdtemp(), "obs.jsonl")
        >>> sink = JsonlSink(path); sink.write({"a": 1}); sink.close()
        >>> read_jsonl(path)
        [{'a': 1}]
    """
    out = []
    with Path(path).open() as fh:
        for i, line in enumerate(fh):
            line = line.strip()
            if not line:
                continue
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{i + 1}: unparseable JSONL line: {e}") from e
    return out


def write_prometheus(registry, path) -> Optional[Path]:
    """Write a registry's Prometheus text exposition to ``path``
    (overwrite; scrape files are snapshots, not logs).

    Example::

        >>> import tempfile, os
        >>> from repro.obs import MetricsRegistry, write_prometheus
        >>> reg = MetricsRegistry()
        >>> reg.counter("requests_total").inc()
        >>> path = os.path.join(tempfile.mkdtemp(), "metrics.prom")
        >>> _ = write_prometheus(reg, path)
        >>> "requests_total 1" in open(path).read()
        True
    """
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(registry.prometheus_text())
    return path
