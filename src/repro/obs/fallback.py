"""Canonical fallback taxonomy + the structured-event emitter.

Every place the fabric stack degrades from its fused shard_map path —
ragged runtime batches, hosts with too few jax devices, replication
fallbacks in sharding resolution, explicitly requested sequential
execution — funnels through :func:`record_fallback`, which emits one
``fabric.fallback`` trace event *and* increments the
``fabric_fallback_total{reason=...}`` counter. The reason strings below
are pinned by ``tests/test_obs.py``; treat them as a wire format, not
prose.
"""

from __future__ import annotations

from typing import Optional, Sequence

from repro.obs import metrics, trace

__all__ = [
    "REASON_RAGGED_BATCH",
    "REASON_INSUFFICIENT_DEVICES",
    "REASON_REPLICATION_FALLBACK",
    "REASON_REQUESTED_SEQUENTIAL",
    "REASON_INELIGIBLE",
    "REASON_NO_BUCKET",
    "FALLBACK_REASONS",
    "classify_fallback",
    "record_fallback",
]

#: Runtime batch not divisible by the mesh's data axis — the fused
#: program cannot shard it, execution drops to the per-layer/per-node loop.
REASON_RAGGED_BATCH = "ragged_batch"
#: Host exposes fewer jax devices than the mapping needs chips.
REASON_INSUFFICIENT_DEVICES = "insufficient_devices"
#: Sharding resolution realized a smaller mesh than requested and
#: replicated the remainder.
REASON_REPLICATION_FALLBACK = "replication_fallback"
#: Caller explicitly asked for the sequential backend.
REASON_REQUESTED_SEQUENTIAL = "requested_sequential"
#: Catch-all for any other compile-time eligibility problem.
REASON_INELIGIBLE = "ineligible"
#: ``fabric.autotune``'s bucketed program cache had no bucket large enough
#: for the request batch — unlike ``ragged_batch``, a ragged batch that DOES
#: fit a bucket is padded, served fused, and records a bucket hit instead.
REASON_NO_BUCKET = "no_bucket"

FALLBACK_REASONS = (
    REASON_RAGGED_BATCH,
    REASON_INSUFFICIENT_DEVICES,
    REASON_REPLICATION_FALLBACK,
    REASON_REQUESTED_SEQUENTIAL,
    REASON_INELIGIBLE,
    REASON_NO_BUCKET,
)


def classify_fallback(problems: Sequence[str]) -> str:
    """Map eligibility problem strings (from ``resolve_backend`` /
    ``graph_eligibility``) onto the canonical reason taxonomy.

    Example::

        >>> from repro.obs import classify_fallback
        >>> classify_fallback(["host has 8 jax device(s) < 16 chips (set XLA_FLAGS=...)"])
        'insufficient_devices'
        >>> classify_fallback(["replication fallback: realized 2x2 != mesh 4x4"])
        'replication_fallback'
        >>> classify_fallback(["weights not quantized"])
        'ineligible'
    """
    joined = " | ".join(problems)
    if "jax device" in joined:
        return REASON_INSUFFICIENT_DEVICES
    if "replication fallback" in joined:
        return REASON_REPLICATION_FALLBACK
    return REASON_INELIGIBLE


def record_fallback(component: str, reason: str, detail: str = "") -> None:
    """Emit one structured fallback record: a ``fabric.fallback`` trace
    event (when tracing) plus a ``fabric_fallback_total{reason=...}``
    counter increment (when collecting). No-op with observability off.

    Example::

        >>> from repro.obs import collecting, record_fallback, tracing
        >>> with tracing() as tr, collecting() as reg:
        ...     record_fallback("fabric.graph", "ragged_batch", "batch 3 % data 2 != 0")
        >>> tr.events[0]["attrs"]["reason"]
        'ragged_batch'
        >>> reg.counter("fabric_fallback_total").value(reason="ragged_batch")
        1.0
    """
    trace.event("fabric.fallback", component=component, reason=reason, detail=detail)
    metrics.inc(
        "fabric_fallback_total",
        help="Fused-path fallbacks by canonical reason.",
        reason=reason,
    )
