"""Lightweight span/tracer API for the fabric serving stack.

Tracing is *contextvar-scoped*, exactly like
``launch.shardings.record_fallbacks``: callers that open a
:func:`tracing` block get every span and event produced inside it
(nesting composes — inner blocks also feed enclosing tracers), and code
outside any block pays near-zero cost — :func:`span` returns one shared
no-op singleton and :func:`event` returns before building a record.

Instrumentation is strictly host-side: spans wall-clock Python-level
work and never touch traced values, so enabling tracing provably cannot
perturb a compiled program — ``GraphProgram.collective_counts`` and the
fused logits are asserted bit-identical with tracing on/off in
``tests/test_obs.py``. The only jax integration is :func:`annotate`,
which wraps a region in ``jax.profiler.TraceAnnotation`` (a profiler
timeline label, invisible to jaxprs) when tracing is enabled.
"""

from __future__ import annotations

import contextlib
import contextvars
import time
from typing import Iterator, List, Optional

from repro.obs.sinks import JsonlSink

__all__ = ["Tracer", "tracing", "span", "event", "enabled", "annotate"]

# Stack of active tracers (innermost last). A ContextVar keeps concurrent
# threads / async serving tasks from seeing each other's spans.
_TRACERS: contextvars.ContextVar[tuple] = contextvars.ContextVar(
    "obs_tracers", default=()
)


class Tracer:
    """Collects finished spans and point events for one :func:`tracing` block.

    ``spans`` / ``events`` are lists of plain dicts (JSON-ready); when the
    block was opened with ``jsonl=path`` every record is also appended to
    that file as one JSON line the moment it is produced.

    Example::

        >>> from repro.obs import tracing, span
        >>> with tracing() as tr:
        ...     with span("demo", layer=0):
        ...         pass
        >>> tr.spans[0]["name"], tr.spans[0]["attrs"]["layer"]
        ('demo', 0)
    """

    def __init__(self, sink: Optional[JsonlSink] = None):
        self.spans: List[dict] = []
        self.events: List[dict] = []
        self._sink = sink

    def _emit(self, record: dict) -> None:
        (self.spans if record["kind"] == "span" else self.events).append(record)
        if self._sink is not None:
            self._sink.write(record)


@contextlib.contextmanager
def tracing(jsonl=None) -> Iterator[Tracer]:
    """Scope span/event recording to a block.

    Every :func:`span` / :func:`event` inside the block lands on the
    yielded :class:`Tracer` (and on any enclosing tracer — nesting
    composes). ``jsonl`` optionally streams each record to a JSONL file
    (:class:`repro.obs.JsonlSink`). Outside any block, instrumentation
    is a no-op.

    Example::

        >>> from repro.obs import tracing, event
        >>> with tracing() as tr:
        ...     event("request.done", tokens=32)
        >>> tr.events[0]["name"]
        'request.done'
    """
    sink = JsonlSink(jsonl) if jsonl is not None else None
    tr = Tracer(sink)
    token = _TRACERS.set(_TRACERS.get() + (tr,))
    try:
        yield tr
    finally:
        _TRACERS.reset(token)
        if sink is not None:
            sink.close()


def enabled() -> bool:
    """Whether any :func:`tracing` block is active in this context.

    Example::

        >>> from repro.obs import enabled, tracing
        >>> enabled()
        False
        >>> with tracing():
        ...     enabled()
        True
    """
    return bool(_TRACERS.get())


class _NullSpan:
    """The shared disabled-path span: every method is a no-op."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs) -> None:
        pass


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("name", "attrs", "_tracers", "_t0")

    def __init__(self, name: str, attrs: dict, tracers: tuple):
        self.name = name
        self.attrs = attrs
        self._tracers = tracers
        self._t0 = 0.0

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def set(self, **attrs) -> None:
        """Attach attributes discovered mid-span (e.g. a resolved backend)."""
        self.attrs.update(attrs)

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        record = {
            "kind": "span",
            "name": self.name,
            "t_s": self._t0,
            "duration_s": t1 - self._t0,
            "attrs": self.attrs,
        }
        for tr in self._tracers:
            tr._emit(record)
        return False


def span(name: str, **attrs):
    """A wall-clock span context manager.

    With no active tracer this returns one shared no-op singleton (zero
    allocation, the documented disabled-path cost); with tracers active
    it records ``{name, t_s, duration_s, attrs}`` to every one of them
    on exit.

    Example::

        >>> from repro.obs import span, tracing
        >>> with tracing() as tr:
        ...     with span("fabric.execute", layer="q_proj") as sp:
        ...         sp.set(tiles=4)
        >>> tr.spans[0]["attrs"]
        {'layer': 'q_proj', 'tiles': 4}
    """
    tracers = _TRACERS.get()
    if not tracers:
        return _NULL_SPAN
    return _Span(name, attrs, tracers)


def event(name: str, **attrs) -> None:
    """Record a point-in-time event (no duration) to every active tracer.

    No-op without an active :func:`tracing` block. The fabric layers use
    this for structured fallback records (``fabric.fallback`` events with
    canonical ``reason`` strings — :mod:`repro.obs.fallback`).

    Example::

        >>> from repro.obs import event, tracing
        >>> with tracing() as tr:
        ...     event("fabric.fallback", reason="ragged_batch")
        >>> tr.events[0]["attrs"]["reason"]
        'ragged_batch'
    """
    tracers = _TRACERS.get()
    if not tracers:
        return
    record = {
        "kind": "event",
        "name": name,
        "t_s": time.perf_counter(),
        "attrs": attrs,
    }
    for tr in tracers:
        tr._emit(record)


def annotate(name: str):
    """A ``jax.profiler.TraceAnnotation`` for ``name`` when tracing is
    enabled, else a null context — the hook that labels the fused
    shard_map programs in ``jax.profiler`` timelines without touching
    their jaxprs (profiler annotations are host-side timeline markers).

    Example::

        >>> from repro.obs import annotate
        >>> with annotate("fabric.graph.fused"):
        ...     pass  # dispatch the fused program here
    """
    if not _TRACERS.get():
        return contextlib.nullcontext()
    import jax

    return jax.profiler.TraceAnnotation(name)
