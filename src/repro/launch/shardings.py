"""Divisibility-aware sharding rules: param tree -> NamedSharding tree.

Logical axes:
  * ``tp``   -> mesh axis ("model",)            tensor parallelism
  * ``fsdp`` -> ("data",) or ("pod", "data")    parameter/optimizer sharding
  * ``dp``   -> ("data",) or ("pod", "data")    batch sharding

A dim that does not divide its assigned mesh axes falls back to replication
for that dim (e.g. kv_heads=8 on a 16-way model axis) — every fallback is
recorded so the dry-run report shows exactly what got replicated.

Fallback records are *scoped*, not global: wrap the spec-building calls in
``with record_fallbacks() as fb:`` and read ``fb`` afterwards. Callers that
don't open a recorder get no bookkeeping and leak nothing — concurrent
serving / planning calls each see only their own records.
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Any, Iterator, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

__all__ = [
    "logical_to_mesh",
    "spec_for",
    "axes_size",
    "sharding_for",
    "param_shardings",
    "batch_shardings",
    "cache_shardings",
    "record_fallbacks",
]

# Stack of active fallback recorders (innermost last). A ContextVar keeps
# concurrent threads / async tasks from seeing each other's records — the
# leak the old module-global FALLBACKS list had.
_RECORDERS: contextvars.ContextVar[tuple] = contextvars.ContextVar(
    "sharding_fallback_recorders", default=()
)


@contextlib.contextmanager
def record_fallbacks() -> Iterator[list[str]]:
    """Scope replication-fallback recording to a block.

    Every ``spec_for`` call inside the block appends its fallback messages to
    the yielded list (and to any enclosing recorder — nesting composes).
    Outside any recorder, fallbacks are simply not recorded.

    Example::

        >>> import numpy as np
        >>> mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
        >>> with record_fallbacks() as fb:
        ...     _ = spec_for(mesh, (16, 32), ("tp", "dp"), "t")
        >>> fb
        []
    """
    rec: list[str] = []
    token = _RECORDERS.set(_RECORDERS.get() + (rec,))
    try:
        yield rec
    finally:
        _RECORDERS.reset(token)


def _record_fallback(msg: str) -> None:
    for rec in _RECORDERS.get():
        rec.append(msg)
    # replication fallbacks double as observability signals: a structured
    # trace event plus a counter, both no-ops unless repro.obs is active
    obs_trace.event("sharding.fallback", detail=msg)
    obs_metrics.inc(
        "sharding_fallback_total",
        help="Parameter/batch sharding dims replicated for non-divisibility.",
    )


def logical_to_mesh(mesh: Mesh) -> dict[str, tuple[str, ...]]:
    multi = "pod" in mesh.axis_names
    dp = ("pod", "data") if multi else ("data",)
    return {"tp": ("model",), "fsdp": dp, "dp": dp}


def axes_size(mesh: Mesh, axes: tuple[str, ...]) -> int:
    """Product of the named mesh-axis sizes (also used by ``fabric.shard``)."""
    return int(np.prod([mesh.shape[a] for a in axes]))


_axes_size = axes_size


def spec_for(
    mesh: Mesh,
    shape: Sequence[int],
    logical: Sequence[Optional[str]],
    label: str = "",
) -> P:
    """Build a PartitionSpec; drop (replicate) any dim that doesn't divide."""
    l2m = logical_to_mesh(mesh)
    entries = []
    for i, (dim, ax) in enumerate(zip(shape, logical)):
        if ax is None:
            entries.append(None)
            continue
        mesh_axes = l2m[ax]
        if dim % _axes_size(mesh, mesh_axes) == 0:
            entries.append(mesh_axes if len(mesh_axes) > 1 else mesh_axes[0])
        else:
            entries.append(None)
            _record_fallback(
                f"{label}: dim {i} ({dim}) not divisible by {ax}{mesh_axes} -> replicated"
            )
    return P(*entries)


def sharding_for(mesh, shape, logical, label="") -> NamedSharding:
    return NamedSharding(mesh, spec_for(mesh, shape, logical, label))


# ---------------------------------------------------------------------------
# Parameter rules (matched by leaf path suffix)
# ---------------------------------------------------------------------------

# name -> logical axes per trailing dim (leading stacked-L dims get None)
_PARAM_RULES: dict[str, tuple] = {
    # embeddings
    "tok": ("tp", "fsdp"),
    "unembed": ("fsdp", "tp"),
    # attention (flattened head dims shard over tp when divisible)
    "wq": ("fsdp", "tp"),
    "wk": ("fsdp", "tp"),
    "wv": ("fsdp", "tp"),
    "wo": ("tp", "fsdp"),
    "bq": ("tp",),
    "bk": ("tp",),
    "bv": ("tp",),
    # dense mlp
    "w_gate": ("fsdp", "tp"),
    "w_up": ("fsdp", "tp"),
    "w_down": ("tp", "fsdp"),
    # moe (expert dim over tp = expert parallelism)
    "router": ("fsdp", None),
    "moe/w_gate": ("tp", "fsdp", None),
    "moe/w_up": ("tp", "fsdp", None),
    "moe/w_down": ("tp", None, "fsdp"),
    # mamba2 (head-aligned dims over tp; guarded by head divisibility)
    "in_z": ("fsdp", "tp"),
    "in_x": ("fsdp", "tp"),
    "in_b": ("fsdp", None),
    "in_c": ("fsdp", None),
    "in_dt": ("fsdp", "tp"),
    "conv_x": (None, "tp"),
    "conv_b": (None, None),
    "conv_c": (None, None),
    "conv_x_bias": ("tp",),
    "conv_b_bias": (None,),
    "conv_c_bias": (None,),
    "A_log": ("tp",),
    "D": ("tp",),
    "dt_bias": ("tp",),
    "norm": ("tp",),
    "out_proj": ("tp", "fsdp"),
    # norms
    "ln": (None,),
    "ln1": (None,),
    "ln2": (None,),
    "ln_f": (None,),
    "mamba_ln": (None,),
}


def _rule_for(path: tuple[str, ...]) -> Optional[tuple]:
    joined = "/".join(path)
    # longest-suffix match, with moe/* taking precedence over plain names
    best = None
    for key, rule in _PARAM_RULES.items():
        if joined.endswith(key):
            if best is None or len(key) > len(best[0]):
                best = (key, rule)
    return best[1] if best else None


def _mamba_heads_shardable(cfg, mesh) -> bool:
    tp = _axes_size(mesh, ("model",))
    return cfg.ssm_state and cfg.ssm_heads % tp == 0


def param_shardings(mesh: Mesh, params_shape: Any, cfg) -> Any:
    """Map a params eval_shape tree to NamedShardings."""
    mamba_tp = _mamba_heads_shardable(cfg, mesh)
    mamba_names = {
        "in_z", "in_x", "in_dt", "conv_x", "conv_x_bias",
        "A_log", "D", "dt_bias", "norm", "out_proj",
    }

    def one(path, leaf):
        keys = tuple(
            p.key if hasattr(p, "key") else str(p) for p in path
        )
        rule = _rule_for(keys)
        shape = leaf.shape
        if rule is None:
            return NamedSharding(mesh, P())
        # mamba leaves fall back to fsdp-only sharding when heads don't divide
        last = keys[-1]
        if last in mamba_names and "mamba" in "/".join(keys) and not mamba_tp:
            rule = tuple("fsdp" if ax == "fsdp" else None for ax in rule)
        n_lead = len(shape) - len(rule)
        logical = (None,) * n_lead + rule
        return sharding_for(mesh, shape, logical, label="/".join(keys))

    return jax.tree_util.tree_map_with_path(one, params_shape)


# ---------------------------------------------------------------------------
# Activations / inputs / caches
# ---------------------------------------------------------------------------


def batch_shardings(mesh: Mesh, batch_shape: Any) -> Any:
    """Token/label/embedding batches: batch dim over dp, rest replicated."""

    def one(leaf):
        logical = ("dp",) + (None,) * (len(leaf.shape) - 1)
        return sharding_for(mesh, leaf.shape, logical, label="batch")

    return jax.tree.map(one, batch_shape)


def cache_shardings(mesh: Mesh, cache_shape: Any, cfg) -> Any:
    """KV / SSM cache shardings for serve steps.

    KV cache leaves are (L, B, S, KV, hd): batch over dp when divisible;
    kv-heads over tp when divisible, OTHERWISE the sequence dim goes over tp
    (flash-decoding-style sequence parallelism — the partial-softmax reduce
    becomes an SPMD collective). Mamba state (L, B, H, P, N): heads over tp.
    """
    l2m = logical_to_mesh(mesh)
    dp_size = _axes_size(mesh, l2m["dp"])
    tp_size = _axes_size(mesh, l2m["tp"])

    def one(path, leaf):
        keys = "/".join(p.key if hasattr(p, "key") else str(p) for p in path)
        shape = leaf.shape
        nd = len(shape)
        if keys.endswith("pos"):
            return NamedSharding(mesh, P())
        if "conv" in keys:  # (L, B, W-1, C)
            logical = (None, "dp" if shape[1] % dp_size == 0 else None, None, None)
            return sharding_for(mesh, shape, logical, label=keys)
        if keys.endswith("ssm"):  # (L, B, H, P, N)
            logical = (
                None,
                "dp" if shape[1] % dp_size == 0 else None,
                "tp" if shape[2] % tp_size == 0 else None,
                None,
                None,
            )
            return sharding_for(mesh, shape, logical, label=keys)
        if nd == 5:  # attn k/v (L, B, S, KV, hd)
            b_ok = shape[1] % dp_size == 0
            kv_ok = shape[3] % tp_size == 0
            logical = (
                None,
                "dp" if b_ok else None,
                None if kv_ok else "tp",
                "tp" if kv_ok else None,
                None,
            )
            if not b_ok and shape[2] % dp_size == 0 and kv_ok:
                # batch=1 long-context: spread the sequence over dp instead
                logical = (None, None, "dp", "tp", None)
            return sharding_for(mesh, shape, logical, label=keys)
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def replicated(mesh: Mesh, tree: Any) -> Any:
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
