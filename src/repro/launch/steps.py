"""Step builders + ShapeDtypeStruct input specs for every (arch × shape) cell.

``input_specs`` allocates nothing — weak-type-correct ShapeDtypeStructs only;
the dry-run lowers against them. The same builders power the real train/serve
drivers (launch/train.py, launch/serve.py).
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.configs.registry import for_shape, get_config
from repro.configs.shapes import SHAPES
from repro.models import build_model
from repro.optim import make_optimizer
from repro.optim.adamw import AdamWState
from repro.optim.adafactor import AdafactorState
from repro.optim.schedules import warmup_cosine
from repro.launch import shardings as sh

__all__ = ["input_specs", "build_cell", "Cell"]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Model-input stand-ins for one cell (no device allocation)."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        if cfg.input_kind == "embeddings":  # modality-frontend stub
            inputs = _sds((b, s, cfg.d_model), cfg.compute_dtype)
        else:
            inputs = _sds((b, s), "int32")
        return {"inputs": inputs, "labels": _sds((b, s), "int32")}
    if shape.kind == "prefill":
        if cfg.input_kind == "embeddings":
            return {"inputs": _sds((b, s, cfg.d_model), cfg.compute_dtype)}
        return {"inputs": _sds((b, s), "int32")}
    # decode: one new token against a cache of seq_len
    if cfg.input_kind == "embeddings":
        token = _sds((b, cfg.d_model), cfg.compute_dtype)
    else:
        token = _sds((b,), "int32")
    return {"token": token, "pos": _sds((), "int32")}


class Cell(NamedTuple):
    arch: str
    shape: str
    cfg: ModelConfig
    fn: Any  # jittable step function
    args: tuple  # ShapeDtypeStruct pytree args
    in_shardings: tuple
    donate: tuple


def build_cell(
    arch: str,
    shape_name: str,
    mesh: Mesh,
    *,
    lr: float = 3e-4,
    cfg_override: ModelConfig | None = None,
) -> Cell:
    """Construct (step_fn, arg specs, shardings) for one dry-run cell."""
    shape = SHAPES[shape_name]
    cfg = cfg_override or for_shape(get_config(arch), shape)
    model = build_model(cfg)

    # activation sharding constraints, read by the model code at trace time
    from repro.models import layers as Lmod

    l2m = sh.logical_to_mesh(mesh)
    import numpy as np

    rules = {
        k: (axes, int(np.prod([mesh.shape[a] for a in axes])))
        for k, axes in (("dp", l2m["dp"]), ("tp", l2m["tp"]))
    }
    rules["mesh"] = mesh
    Lmod.set_act_rules(rules)

    params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    params_sh = sh.param_shardings(mesh, params_sds, cfg)
    specs = input_specs(cfg, shape)

    if shape.kind == "train":
        opt_init, opt_update = make_optimizer(cfg.optimizer)
        opt_sds = jax.eval_shape(opt_init, params_sds)
        rep = NamedSharding(mesh, P())
        if cfg.optimizer == "adamw":
            opt_sh = AdamWState(m=params_sh, v=params_sh, count=rep)
        else:

            def vr_sh(p_shd, p_sds):
                if len(p_sds.shape) < 2:
                    return rep
                spec = tuple(p_shd.spec)
                spec = spec + (None,) * (len(p_sds.shape) - len(spec))
                return NamedSharding(mesh, P(*spec[:-1]))

            def vc_sh(p_shd, p_sds):
                if len(p_sds.shape) < 2:
                    return rep
                spec = list(tuple(p_shd.spec) + (None,) * (len(p_sds.shape) - len(tuple(p_shd.spec))))
                del spec[-2]
                return NamedSharding(mesh, P(*spec))

            opt_sh = AdafactorState(
                v_row=jax.tree.map(vr_sh, params_sh, params_sds),
                v_col=jax.tree.map(vc_sh, params_sh, params_sds),
                v_full=jax.tree.map(lambda p_shd, p_sds: rep if len(p_sds.shape) >= 2 else p_shd, params_sh, params_sds),
                count=rep,
            )
        batch_sh = sh.batch_shardings(mesh, specs)
        step_sh = NamedSharding(mesh, P())

        def train_step(params, opt_state, batch, step):
            def loss_of(p):
                loss, mets = model.loss_fn(p, batch)
                return loss, mets

            (loss, mets), grads = jax.value_and_grad(loss_of, has_aux=True)(params)
            lr_t = warmup_cosine(step, lr, warmup=2000, total=100_000)
            new_params, new_opt, opt_mets = opt_update(grads, opt_state, params, lr_t)
            metrics = {"loss": loss, **mets, **opt_mets, "lr": lr_t}
            return new_params, new_opt, metrics

        args = (params_sds, opt_sds, specs, _sds((), "int32"))
        in_sh = (params_sh, opt_sh, batch_sh, step_sh)
        return Cell(arch, shape_name, cfg, train_step, args, in_sh, donate=(0, 1))

    if shape.kind == "prefill":
        cache_sds = jax.eval_shape(
            functools.partial(model.make_cache, shape.global_batch, shape.seq_len)
        )
        cache_sh = sh.cache_shardings(mesh, cache_sds, cfg)
        batch_sh = sh.batch_shardings(mesh, specs)

        def prefill_step(params, inputs, cache):
            return model.prefill(params, inputs, cache)

        args = (params_sds, specs["inputs"], cache_sds)
        in_sh = (params_sh, batch_sh["inputs"], cache_sh)
        return Cell(arch, shape_name, cfg, prefill_step, args, in_sh, donate=(2,))

    # decode
    cache_sds = jax.eval_shape(
        functools.partial(model.make_cache, shape.global_batch, shape.seq_len)
    )
    cache_sh = sh.cache_shardings(mesh, cache_sds, cfg)
    tok_sds = specs["token"]
    dp = sh.logical_to_mesh(mesh)["dp"]
    import numpy as np

    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    tok_logical = ("dp",) + (None,) * (len(tok_sds.shape) - 1)
    if tok_sds.shape and tok_sds.shape[0] % dp_size == 0:
        tok_sh = sh.sharding_for(mesh, tok_sds.shape, tok_logical, "token")
    else:
        tok_sh = NamedSharding(mesh, P())

    def decode_step(params, token, pos, cache):
        return model.decode_step(params, token, pos, cache)

    args = (params_sds, tok_sds, specs["pos"], cache_sds)
    in_sh = (params_sh, tok_sh, NamedSharding(mesh, P()), cache_sh)
    return Cell(arch, shape_name, cfg, decode_step, args, in_sh, donate=(3,))
