"""Batched serving driver: continuous prefill + decode over a request queue.

Requests arrive with different prompt lengths; the driver pads each to the
cache size, runs one batched prefill, then steps decode for all sequences in
lock-step (static batch, the classic TPU serving layout). Supports the
paper's CiM-quantized inference mode (--cim fake_quant) — the technique as a
deployable serving feature.

CLI (CPU-scale): examples/serve_lm.py wraps this.
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, reduced
from repro.configs.registry import get_config
from repro.core.cim_linear import CiMConfig
from repro.models import build_model

__all__ = ["ServeSettings", "serve_batch"]


@dataclasses.dataclass
class ServeSettings:
    batch: int = 4
    prompt_len: int = 32
    gen_len: int = 32
    seed: int = 0
    greedy: bool = True


def serve_batch(cfg: ModelConfig, st: ServeSettings, prompts: Optional[np.ndarray] = None):
    """Serve one static batch: returns dict with tokens + timing."""
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(st.seed))
    rng = np.random.default_rng(st.seed)
    if prompts is None:
        prompts = rng.integers(0, cfg.vocab, (st.batch, st.prompt_len)).astype(np.int32)
    b, s = prompts.shape
    total = s + st.gen_len

    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    t0 = time.time()
    cache = model.make_cache(b, total)
    logits, cache = prefill(params, jnp.asarray(prompts), cache)
    next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    t_prefill = time.time() - t0

    out_tokens = [next_tok]
    t0 = time.time()
    for i in range(st.gen_len - 1):
        pos = jnp.asarray(s + i, jnp.int32)
        logits, cache = decode(params, next_tok, pos, cache)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        out_tokens.append(next_tok)
    jax.block_until_ready(next_tok)
    t_decode = time.time() - t0

    gen = np.stack([np.asarray(t) for t in out_tokens], axis=1)
    return {
        "prompts": prompts,
        "generated": gen,
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "decode_tok_s": b * (st.gen_len - 1) / max(t_decode, 1e-9),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--cim", default=None, choices=[None, "fake_quant", "bitplane"])
    ap.add_argument(
        "--fabric",
        default=None,
        choices=[None, "pair_sar", "flash", "hybrid"],
        help="also map the model onto a chip-level CiM fabric and print the "
        "area/energy/latency/EMA rollup (repro.fabric)",
    )
    ap.add_argument("--fabric-arrays", type=int, default=256)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if args.cim:
        import dataclasses as dc

        cfg = dc.replace(cfg, cim=CiMConfig(mode=args.cim, ste=False))
    st = ServeSettings(batch=args.batch, prompt_len=args.prompt_len, gen_len=args.gen_len)
    out = serve_batch(cfg, st)
    print(
        f"[serve] {args.arch}: prefill {out['prefill_s']*1e3:.1f} ms, "
        f"decode {out['decode_tok_s']:.1f} tok/s "
        f"(batch {st.batch}, +{st.gen_len} tokens)"
    )
    print("[serve] sample generation:", out["generated"][0][:16].tolist())

    if args.fabric:
        from repro.fabric import FabricConfig, fabric_report, map_model, render_markdown

        fb = FabricConfig(mode=args.fabric, n_arrays=args.fabric_arrays)
        placements = map_model(cfg, fb, tokens=1)
        print()
        print(render_markdown(fabric_report(placements, fb)))


if __name__ == "__main__":
    main()
