"""Batched serving driver: continuous prefill + decode over a request queue.

Requests arrive with different prompt lengths; the driver pads each to the
cache size, runs one batched prefill, then steps decode for all sequences in
lock-step (static batch, the classic TPU serving layout). Supports the
paper's CiM-quantized inference mode (--cim fake_quant) — the technique as a
deployable serving feature.

CLI (CPU-scale): examples/serve_lm.py wraps this.
"""

from __future__ import annotations

import argparse
import contextlib
import dataclasses
import functools
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, reduced
from repro.configs.registry import get_config
from repro.core.cim_linear import CiMConfig
from repro.models import build_model
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

__all__ = ["ServeSettings", "serve_batch", "parse_fabric_mesh", "compiled_model"]


@functools.lru_cache(maxsize=8)
def compiled_model(cfg: ModelConfig, seed: int):
    """Build + initialize ``cfg`` and wrap its prefill/decode in ``jax.jit``
    ONCE per ``(cfg, seed)``.

    ``serve_batch`` used to rebuild the model and re-wrap ``jax.jit`` on
    every call, which discarded the trace cache and re-traced (and
    re-compiled) prefill and decode each time; hoisting the wrappers here
    makes repeated ``serve_batch`` calls — the continuous-batching serving
    loop — reuse the compiled executables. ``ModelConfig`` is a frozen
    dataclass, so it keys the LRU directly.

    Returns ``(model, params, jit_prefill, jit_decode)``.
    """
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    return model, params, jax.jit(model.prefill), jax.jit(model.decode_step)


def parse_fabric_mesh(spec: str) -> tuple:
    """Parse a ``--fabric-mesh`` ``DxM`` spec (e.g. ``2x4``) into
    ``(data, model)`` and validate it against
    ``repro.launch.mesh.make_chip_mesh`` — the same axis rules the shard
    planner uses, so a spec that parses here is a mesh the planner accepts.

    Example::

        >>> parse_fabric_mesh("2x4")
        (2, 4)
    """
    parts = spec.lower().replace(" ", "").split("x")
    if len(parts) != 2:
        raise ValueError(f"--fabric-mesh wants DxM (e.g. 2x4), got {spec!r}")
    try:
        data, model = int(parts[0]), int(parts[1])
    except ValueError:
        raise ValueError(f"--fabric-mesh wants integer axes, got {spec!r}") from None
    from repro.launch.mesh import make_chip_mesh

    make_chip_mesh(data, model)  # raises on axes < 1; abstract fallback is fine
    return data, model


@dataclasses.dataclass
class ServeSettings:
    batch: int = 4
    prompt_len: int = 32
    gen_len: int = 32
    seed: int = 0
    greedy: bool = True


def serve_batch(
    cfg: ModelConfig,
    st: ServeSettings,
    prompts: Optional[np.ndarray] = None,
    fabric_rollup: Optional[dict] = None,
):
    """Serve one static batch: returns dict with tokens + timing.

    ``fabric_rollup`` (a ``fabric_report`` / ``sharded_fabric_report`` dict
    for ONE forward pass) turns the batching log line into a per-request cost
    model: estimated CiM latency / energy / EMA per request are printed with
    the batch and folded into the returned dict — the first step of
    fabric-aware batching decisions (ROADMAP).

    With ``repro.obs`` metrics collection active (serve CLI:
    ``--obs-metrics``) the batching log line is replaced by the per-request
    observability summary — fused/fallback request counters, conversion and
    link-bit totals, and the measured-vs-modeled link latency with the named
    ``link_clock_calibration`` constant — read back from the live registry.
    """
    model, params, prefill, decode = compiled_model(cfg, st.seed)
    rng = np.random.default_rng(st.seed)
    if prompts is None:
        prompts = rng.integers(0, cfg.vocab, (st.batch, st.prompt_len)).astype(np.int32)
    b, s = prompts.shape
    total = s + st.gen_len

    t0 = time.time()
    with obs_trace.span("serve.prefill", batch=b, prompt_len=s):
        cache = model.make_cache(b, total)
        logits, cache = prefill(params, jnp.asarray(prompts), cache)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        jax.block_until_ready(next_tok)
    t_prefill = time.time() - t0

    out_tokens = [next_tok]
    t0 = time.time()
    with obs_trace.span("serve.decode", batch=b, gen_len=st.gen_len):
        for i in range(st.gen_len - 1):
            pos = jnp.asarray(s + i, jnp.int32)
            logits, cache = decode(params, next_tok, pos, cache)
            next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            out_tokens.append(next_tok)
        jax.block_until_ready(next_tok)
    t_decode = time.time() - t0

    obs_metrics.inc("serve_requests_total", b, help="Requests served (batch slots).")
    obs_metrics.observe(
        "serve_prefill_seconds", t_prefill, help="Batched prefill wall time."
    )
    obs_metrics.observe(
        "serve_decode_seconds", t_decode, help="Batched decode wall time."
    )

    gen = np.stack([np.asarray(t) for t in out_tokens], axis=1)
    out = {
        "prompts": prompts,
        "generated": gen,
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "decode_tok_s": b * (st.gen_len - 1) / max(t_decode, 1e-9),
    }
    if fabric_rollup is not None:
        t = fabric_rollup["totals"]
        # the rollup maps one batched forward pass (tokens = batch); prefill
        # runs s token positions, decode gen_len - 1 more, so a request costs
        # (s + gen_len - 1) passes shared across the b requests of the batch
        passes = (s + st.gen_len - 1) / b
        xchip_bits = t.get("crosschip_bits_per_pass", 0)
        # mesh rollups carry the double-buffered round-overlap latency
        # (reduce-scatter of layer i hidden under layer i+1's conversions)
        latency_s = t.get("latency_s_overlapped", t["latency_s"])
        fab = {
            "latency_s_per_request": latency_s * passes,
            "energy_uj_per_request": (
                t["digitization_energy_pj"]
                + t["ema_energy_pj"]
                + t.get("crosschip_energy_pj", 0.0)
            )
            * passes
            / 1e6,
            "onchip_ema_bits_per_request": t["ema_bits_per_pass"] * passes,
            "crosschip_bits_per_request": xchip_bits * passes,
            "model_resident": t["model_resident"],
            "n_chips": fabric_rollup.get("mesh", {}).get("n_chips", 1),
            "exec_backend": fabric_rollup.get("exec_backend", "n/a"),
        }
        out["fabric"] = fab
        if obs_metrics.active():
            # the per-request observability summary line: live counters from
            # the registry (fed by the fabric layers + the validation pass)
            # replace the static cost-model printout
            obs_metrics.inc(
                "fabric_ema_bits_total",
                fab["onchip_ema_bits_per_request"] * b,
                help="On-chip external-memory-access bits for requests served.",
            )
            fused = obs_metrics.get_value("fabric_requests_total", path="fused")
            fell = obs_metrics.get_value("fabric_requests_total", path="fallback")
            conv = obs_metrics.get_value("fabric_conversions_total")
            bits = obs_metrics.get_value("fabric_link_bits_total")
            modeled = obs_metrics.get_value("fabric_modeled_link_seconds")
            measured = obs_metrics.get_value("fabric_measured_collective_seconds")
            calib = obs_metrics.get_value("fabric_link_clock_calibration")
            obs_trace.event(
                "serve.request_summary", batch=b, total_tokens=total,
                fused_requests=fused, fallback_requests=fell,
                conversions=conv, link_bits=bits,
                modeled_link_s=modeled, measured_collective_s=measured,
                link_clock_calibration=calib,
            )
            print(
                f"[serve] obs batch {b}x{total} tok on {fab['n_chips']} chip(s) "
                f"[{fab['exec_backend']}]: fused {fused:.0f} / fallback "
                f"{fell:.0f} requests; {conv:.3g} conversions, "
                f"{bits:.3g} link bits; link modeled {modeled:.3g} s vs "
                f"measured {measured:.3g} s "
                f"(link_clock_calibration {calib:.3g}); est. "
                f"{fab['latency_s_per_request']*1e3:.3g} ms, "
                f"{fab['energy_uj_per_request']:.3g} uJ per request"
            )
        else:
            print(
                f"[serve] batch {b}x{total} tok on {fab['n_chips']} chip(s) "
                f"[{fab['exec_backend']}]: est. "
                f"{fab['latency_s_per_request']*1e3:.3g} ms, "
                f"{fab['energy_uj_per_request']:.3g} uJ per request "
                f"(on-chip EMA {fab['onchip_ema_bits_per_request']:.3g} bits, "
                f"cross-chip {fab['crosschip_bits_per_request']:.3g} bits, "
                f"{'resident' if fab['model_resident'] else 'reloading'})"
            )
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--cim", default=None, choices=[None, "fake_quant", "bitplane"])
    ap.add_argument(
        "--fabric",
        default=None,
        choices=[None, "pair_sar", "flash", "hybrid"],
        help="also map the model onto a chip-level CiM fabric and print the "
        "area/energy/latency/EMA rollup (repro.fabric)",
    )
    ap.add_argument("--fabric-arrays", type=int, default=256)
    ap.add_argument(
        "--fabric-chips",
        type=int,
        default=1,
        choices=[1, 4, 16],
        help="square-mesh sugar for --fabric-mesh (1 -> 1x1, 4 -> 2x2, "
        "16 -> 4x4; repro.fabric.shard)",
    )
    ap.add_argument(
        "--fabric-mesh",
        default=None,
        metavar="DxM",
        help="explicit (data x model) chip mesh, e.g. 2x4 — any axes "
        "repro.launch.mesh.make_chip_mesh accepts; overrides the "
        "--fabric-chips sugar (passing both is an error)",
    )
    ap.add_argument(
        "--fabric-backend",
        default="auto",
        choices=["auto", "sequential", "shard_map"],
        help="chip execution backend for the fabric validation pass: "
        "sequential host loop, real multi-device shard_map, or auto "
        "(shard_map when the host has the devices; repro.fabric.resolve_backend)",
    )
    ap.add_argument(
        "--fabric-program",
        action="store_true",
        help="run the whole-model fused shard_map forward "
        "(repro.fabric.compile_forward, one block chain) as the validation "
        "pass and report measured-vs-modeled link latency",
    )
    ap.add_argument(
        "--fabric-scan",
        action="store_true",
        help="compile the --fabric-program graph validation pass with "
        "scan_layers=True (repro.fabric.compile_graph_forward): the FULL "
        "model's repeated block traces once and runs under lax.scan — "
        "depth-constant compile time for deep registry configs "
        "(dense/moe families only)",
    )
    ap.add_argument(
        "--fabric-autotune",
        action="store_true",
        help="pick the (data x model) mesh and batch-bucket boundaries from "
        "the graph cost model (repro.fabric.autotune) for a synthetic "
        "ragged request mix, then validate a ragged batch through the "
        "bucketed fused-program cache (bit-exact to the per-node "
        "reference after pad-slicing)",
    )
    ap.add_argument(
        "--obs-log",
        default=None,
        metavar="PATH",
        help="stream repro.obs spans/events (fabric fallbacks, serve "
        "prefill/decode, request summaries) to PATH as JSONL",
    )
    ap.add_argument(
        "--obs-metrics",
        action="store_true",
        help="collect repro.obs metrics for the whole run: the batching log "
        "becomes the per-request obs summary line and the Prometheus text "
        "exposition prints at exit",
    )
    ap.add_argument(
        "--obs-metrics-out",
        default=None,
        metavar="PATH",
        help="write the Prometheus exposition to PATH instead of stdout "
        "(implies --obs-metrics)",
    )
    args = ap.parse_args()

    with contextlib.ExitStack() as stack:
        if args.obs_log:
            stack.enter_context(obs_trace.tracing(jsonl=args.obs_log))
        reg = None
        if args.obs_metrics or args.obs_metrics_out:
            reg = stack.enter_context(obs_metrics.collecting())
        _serve_main(args, ap)
        if args.obs_log:
            print(f"[serve] obs JSONL event log: {args.obs_log}")
        if reg is not None:
            if args.obs_metrics_out:
                from repro.obs.sinks import write_prometheus

                write_prometheus(reg, args.obs_metrics_out)
                print(f"[serve] obs metrics exposition: {args.obs_metrics_out}")
            else:
                print("\n[serve] obs metrics exposition:")
                print(reg.prometheus_text(), end="")


def _serve_main(args, ap):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if args.cim:
        import dataclasses as dc

        cfg = dc.replace(cfg, cim=CiMConfig(mode=args.cim, ste=False))
    st = ServeSettings(batch=args.batch, prompt_len=args.prompt_len, gen_len=args.gen_len)

    if (
        args.fabric_chips > 1 or args.fabric_mesh or args.fabric_program
        or args.fabric_autotune
    ) and not args.fabric:
        ap.error(
            "--fabric-chips/--fabric-mesh/--fabric-program/--fabric-autotune "
            "require --fabric"
        )
    if args.fabric_autotune and cfg.family not in ("dense", "moe"):
        ap.error(
            f"--fabric-autotune needs a matmul-graph family (dense/moe); "
            f"{args.arch} is {cfg.family!r}"
        )
    if args.fabric_scan and not args.fabric_program:
        ap.error("--fabric-scan requires --fabric-program")
    if args.fabric_scan and cfg.family not in ("dense", "moe"):
        ap.error(
            f"--fabric-scan needs a matmul-graph family (dense/moe); "
            f"{args.arch} is {cfg.family!r}"
        )
    if args.fabric_mesh and args.fabric_chips > 1:
        ap.error("pass either --fabric-mesh or the --fabric-chips sugar, not both")
    rollup = None
    if args.fabric:
        # map (and optionally shard) BEFORE serving so the batching log line
        # carries the per-request fabric cost, not just a post-hoc printout;
        # one mapped pass covers the whole lock-step batch (tokens = batch),
        # which is what lets the mesh's data axis actually split work
        import jax as _jax

        from repro.fabric import (
            ChipMeshConfig,
            FabricConfig,
            execute_sharded_matmul,
            fabric_report,
            map_matmul,
            map_model,
            resolve_backend,
            shard_model,
            shard_placement,
            sharded_fabric_report,
        )

        fb = FabricConfig(mode=args.fabric, n_arrays=args.fabric_arrays)
        if args.fabric_mesh:
            try:
                mesh_d, mesh_m = parse_fabric_mesh(args.fabric_mesh)
            except ValueError as e:
                ap.error(str(e))
        else:
            side = {1: 1, 4: 2, 16: 4}[args.fabric_chips]
            mesh_d = mesh_m = side
        if mesh_d * mesh_m > 1:
            cm = ChipMeshConfig(data=mesh_d, model=mesh_m, fabric=fb)
            sps = shard_model(cfg, cm, tokens=st.batch)
            rollup = sharded_fabric_report(sps, cm)
        else:
            cm = ChipMeshConfig(fabric=fb)
            sps = []
            rollup = fabric_report(map_model(cfg, fb, tokens=st.batch), fb)

        # resolve the backend against the REAL model placements: one layer
        # with a replication fallback is enough to keep the whole pass
        # sequential (and an explicit shard_map request fails loudly on it)
        smoke_m, smoke_k, smoke_n = 2 * cm.data, cm.model * fb.rows, fb.cols
        sp = shard_placement(map_matmul("smoke", smoke_m, smoke_k, smoke_n, fb), cm)
        resolved = {resolve_backend(p, args.fabric_backend) for p in sps or [sp]}
        backend = "sequential" if "sequential" in resolved else "shard_map"
        # numeric backend validation: run one mesh-divisible matmul through
        # the resolved backend so the log line reports a path that executed
        skey = _jax.random.PRNGKey(0)
        x_s = _jax.random.normal(skey, (smoke_m, smoke_k))
        w_s = _jax.random.normal(_jax.random.fold_in(skey, 1), (smoke_k, smoke_n))
        from repro.core.cim_linear import CiMConfig as _CiM

        execute_sharded_matmul(
            x_s, w_s, cm,
            _CiM(mode="bitplane", a_bits=4, w_bits=4, adc_bits=fb.adc_bits,
                 rows=fb.rows, ste=False),
            sharded=sp, backend=backend,
        )
        rollup["exec_backend"] = backend
        print(
            f"[serve] fabric exec backend: {backend} "
            f"({len(_jax.devices())} jax device(s) for {cm.n_chips} chip(s))"
        )

        if args.fabric_program:
            # fused forward as the validation pass: the full-transformer-
            # block GRAPH (siblings, attention mixing, norms, residuals —
            # repro.fabric.graph) for families with a matmul-graph forward,
            # the residual-CHAIN program (repro.fabric.program) for the
            # rest (mamba/hybrid). Either way the fused path falls back to
            # its reference loop (with printed reasons) when the served
            # model's shapes are not eligible on this mesh.
            import numpy as _np

            from repro.fabric import measure_forward

            val_cim = _CiM(
                mode="bitplane", a_bits=4, w_bits=4, adc_bits=fb.adc_bits,
                rows=fb.rows, ste=False,
            )
            if cfg.family in ("dense", "moe"):
                from repro.fabric import compile_graph_forward
                from repro.fabric.report import graph_section

                # --fabric-scan validates the FULL model (the scan is what
                # makes its compile depth-constant); otherwise one block
                prog = compile_graph_forward(
                    cfg, cm, cim=val_cim, backend=args.fabric_backend,
                    tokens=st.batch, block_only=not args.fabric_scan,
                    scan_layers=args.fabric_scan,
                )
                xp = _jax.random.normal(
                    _jax.random.PRNGKey(2), (st.batch, 1, prog.d_in)
                )
                rollup["graph"] = graph_section(prog.graph, cm.model, program=prog)
                if args.fabric_scan:
                    desc = (f"graph: scanned {prog.n_blocks}-block model "
                            f"({len(prog.placements)} matmuls, block traced once)")
                else:
                    desc = (f"graph: {len(prog.graph.nodes)}-node block "
                            f"({len(prog.placements)} matmuls)")
                ref_name = "per-node loop"
            else:
                from repro.fabric import compile_forward

                prog = compile_forward(
                    cfg, cm, cim=val_cim, backend=args.fabric_backend,
                    tokens=st.batch, block_only=True,
                )
                xp = prog.example_input(_jax.random.PRNGKey(2))
                desc = f"chain: {prog.n_layers}-layer block"
                ref_name = "per-layer loop"
            wsp = prog.random_weights(_jax.random.PRNGKey(3))
            y_f = prog(xp, wsp)
            y_l = prog.reference_forward(xp, wsp, backend="sequential")
            maxdiff = float(_np.abs(_np.asarray(y_f) - _np.asarray(y_l)).max())
            # reference baseline on the sequential loop: the auto-fallback
            # path, and cheap enough to keep serving startup interactive
            measured = measure_forward(
                prog, x=xp, weights=wsp, iters=1,
                per_layer_backend="sequential", per_layer_iters=1,
            )
            measured["max_abs_diff_vs_per_layer"] = maxdiff
            rollup["program_validation"] = measured
            mc = measured.get("measured_collective_s")
            print(
                f"[serve] fused {desc} on {prog.backend}"
                + (f" (fallback: {'; '.join(prog.problems)})" if prog.problems else "")
                + f", maxdiff {maxdiff:.2e} vs {ref_name}; collectives "
                + (f"{mc*1e3:.3g} ms wall" if mc is not None else "n/a")
                + f" vs modeled link {measured['modeled_link_s']*1e3:.3g} ms"
            )

        if args.fabric_autotune:
            # cost-model-driven continuous batching: pick mesh + bucket
            # boundaries for a synthetic ragged request mix (every batch
            # size up to --batch, uniform — a stand-in for a measured
            # trace), then validate one ragged batch through the bucketed
            # fused-program cache against the per-node reference
            import numpy as _np

            from repro.fabric import (
                BucketedGraphCache,
                autotune_plan,
                autotune_section,
                request_histogram,
            )

            at_cim = _CiM(
                mode="bitplane", a_bits=4, w_bits=4, adc_bits=fb.adc_bits,
                rows=fb.rows, ste=False,
            )
            hist = request_histogram(range(1, st.batch + 1))
            plan = autotune_plan(
                cfg, hist, cm.n_chips, fb, cim=at_cim,
                default_mesh=(mesh_d, mesh_m),
            )
            plan_cm = ChipMeshConfig(data=plan.data, model=plan.model, fabric=fb)
            cache = BucketedGraphCache(
                cfg, plan_cm, at_cim, buckets=plan.buckets,
                block_only=not args.fabric_scan, scan_layers=args.fabric_scan,
            )
            # a batch the plan's data axis does NOT divide, when one exists
            b_val = next(
                (b for b in range(st.batch, 0, -1) if b % plan.data),
                st.batch,
            )
            prog = cache.program_for(cache.bucket_for(b_val))
            w_at = prog.random_weights(_jax.random.PRNGKey(3))
            x_at = _jax.random.normal(_jax.random.PRNGKey(2), (b_val, 1, prog.d_in))
            y_bucketed = cache(x_at, w_at)
            y_ref = prog.reference_forward(x_at, w_at)
            at_diff = float(_np.abs(_np.asarray(y_bucketed) - _np.asarray(y_ref)).max())
            rollup["autotune"] = autotune_section(plan, cache)
            print(
                f"[serve] autotune: mesh {plan.data}x{plan.model}, buckets "
                f"{list(plan.buckets)} ({plan.searched} plans searched); "
                f"expected {plan.expected_latency_s*1e3:.3g} ms/request vs "
                f"baseline {plan.baseline_latency_s*1e3:.3g} ms; ragged "
                f"B={b_val} via bucketed fused path, maxdiff {at_diff:.2e} "
                f"vs per-node reference"
            )

    out = serve_batch(cfg, st, fabric_rollup=rollup)
    print(
        f"[serve] {args.arch}: prefill {out['prefill_s']*1e3:.1f} ms, "
        f"decode {out['decode_tok_s']:.1f} tok/s "
        f"(batch {st.batch}, +{st.gen_len} tokens)"
    )
    print("[serve] sample generation:", out["generated"][0][:16].tolist())

    if rollup is not None:
        from repro.fabric import render_markdown

        print()
        print(render_markdown(rollup))


if __name__ == "__main__":
    main()
