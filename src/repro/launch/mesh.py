"""Production mesh construction (function, not module constant — importing
this module never touches jax device state)."""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "make_chip_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = (data, model) = 256 chips.
    Multi-pod: (2, 16, 16) = (pod, data, model) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Degenerate 1x1 mesh over the local device (smoke tests / examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_chip_mesh(data: int = 1, model: int = 1):
    """``(data, model)`` mesh for the multi-chip CiM fabric (``fabric.shard``).

    Returns a concrete device mesh when the host has ``data * model`` jax
    devices, otherwise an :class:`jax.sharding.AbstractMesh` of the same shape
    — the planning paths (``shardings.spec_for`` divisibility checks, traffic
    models) only read ``shape`` / ``axis_names``, so a 16-chip fabric can be
    sized and swept on a single-device host.

    Example::

        >>> mesh = make_chip_mesh(data=2, model=2)
        >>> dict(mesh.shape)
        {'data': 2, 'model': 2}
    """
    if data < 1 or model < 1:
        raise ValueError(f"mesh axes must be >= 1, got data={data}, model={model}")
    if len(jax.devices()) >= data * model:
        return jax.make_mesh((data, model), ("data", "model"))
    from jax.sharding import AbstractMesh

    return AbstractMesh((("data", data), ("model", model)))
