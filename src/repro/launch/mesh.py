"""Production mesh construction (function, not module constant — importing
this module never touches jax device state)."""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "make_chip_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = (data, model) = 256 chips.
    Multi-pod: (2, 16, 16) = (pod, data, model) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Degenerate 1x1 mesh over the local device (smoke tests / examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_chip_mesh(data: int = 1, model: int = 1, *, require_concrete: bool = False):
    """``(data, model)`` mesh for the multi-chip CiM fabric (``fabric.shard``).

    Returns a concrete device mesh when the host has ``data * model`` jax
    devices, otherwise an :class:`jax.sharding.AbstractMesh` of the same shape
    — the planning paths (``shardings.spec_for`` divisibility checks, traffic
    models) only read ``shape`` / ``axis_names``, so a 16-chip fabric can be
    sized and swept on a single-device host.

    The device-count check happens HERE, deterministically, before any jax
    mesh is built: execution paths that need real devices (the ``shard_map``
    backend of ``fabric.shard.execute_sharded_matmul``) pass
    ``require_concrete=True`` and get an immediate, actionable error instead
    of an opaque failure deep inside ``shard_map``.

    Example::

        >>> mesh = make_chip_mesh(data=2, model=2)
        >>> dict(mesh.shape)
        {'data': 2, 'model': 2}
    """
    if data < 1 or model < 1:
        raise ValueError(f"mesh axes must be >= 1, got data={data}, model={model}")
    n_needed = data * model
    n_have = len(jax.devices())
    if n_have >= n_needed:
        return jax.make_mesh((data, model), ("data", "model"))
    if require_concrete:
        raise RuntimeError(
            f"make_chip_mesh({data}, {model}) needs {n_needed} jax devices but the "
            f"host has {n_have}; run on more devices or force host devices with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n_needed}"
        )
    from jax.sharding import AbstractMesh

    return AbstractMesh((("data", data), ("model", model)))
