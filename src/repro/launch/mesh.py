"""Production mesh construction (function, not module constant — importing
this module never touches jax device state)."""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = (data, model) = 256 chips.
    Multi-pod: (2, 16, 16) = (pod, data, model) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Degenerate 1x1 mesh over the local device (smoke tests / examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))
