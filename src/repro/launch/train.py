"""End-to-end training driver: data -> pjit train step -> checkpoint/restart.

Production pieces wired together: sharded step (same builders as the
dry-run), microbatch gradient accumulation, optional int8 gradient
compression on the DP all-reduce, async atomic checkpoints, watchdog
straggler detection, supervised restart, seekable data.

CLI (CPU-scale example — examples/train_lm.py wraps this):
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --reduced \
      --steps 50 --batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from functools import partial
from pathlib import Path
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import Checkpointer, latest_step, restore
from repro.configs.base import ModelConfig, reduced
from repro.configs.registry import get_config
from repro.data.tokens import TokenPipeline
from repro.ft.watchdog import Watchdog, run_with_restart
from repro.launch import shardings as sh
from repro.launch.mesh import make_local_mesh
from repro.models import build_model
from repro.models import layers as Lmod
from repro.optim import make_optimizer
from repro.optim.schedules import warmup_cosine

__all__ = ["TrainSettings", "train"]


@dataclasses.dataclass
class TrainSettings:
    steps: int = 50
    batch: int = 8
    seq: int = 128
    lr: float = 3e-4
    warmup: int = 10
    microbatches: int = 1  # gradient accumulation
    grad_compression: bool = False
    ckpt_dir: str = "results/ckpt"
    ckpt_every: int = 25
    keep_last: int = 3
    seed: int = 0
    log_every: int = 10


def _build_step(model, cfg: ModelConfig, st: TrainSettings, mesh):
    opt_init, opt_update = make_optimizer(cfg.optimizer)
    l2m = sh.logical_to_mesh(mesh)
    Lmod.set_act_rules(
        {
            k: (axes, int(np.prod([mesh.shape[a] for a in axes])))
            for k, axes in (("dp", l2m["dp"]), ("tp", l2m["tp"]))
        }
    )

    def train_step(params, opt_state, batch, step):
        def loss_of(p, b):
            loss, mets = model.loss_fn(p, b)
            return loss, mets

        if st.microbatches > 1:
            # gradient accumulation over sequential microbatches
            mb = jax.tree.map(
                lambda x: x.reshape(st.microbatches, -1, *x.shape[1:]), batch
            )

            def acc_fn(carry, mbi):
                g_acc, l_acc = carry
                (loss, _), g = jax.value_and_grad(loss_of, has_aux=True)(params, mbi)
                return (jax.tree.map(jnp.add, g_acc, g), l_acc + loss), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = jax.lax.scan(acc_fn, (zeros, 0.0), mb)
            grads = jax.tree.map(lambda g: g / st.microbatches, grads)
            loss = loss_sum / st.microbatches
            mets = {}
        else:
            (loss, mets), grads = jax.value_and_grad(loss_of, has_aux=True)(
                params, batch
            )
        lr_t = warmup_cosine(step, st.lr, st.warmup, st.steps)
        new_params, new_opt, opt_mets = opt_update(grads, opt_state, params, lr_t)
        return new_params, new_opt, {"loss": loss, "lr": lr_t, **mets, **opt_mets}

    return opt_init, jax.jit(train_step, donate_argnums=(0, 1))


def train(
    cfg: ModelConfig,
    st: TrainSettings,
    mesh=None,
    resume: Optional[int] = None,
    stop_at: Optional[int] = None,
) -> dict:
    """``stop_at`` simulates an interruption at that step (tests/FT drills)
    while keeping the LR schedule defined by ``st.steps``."""
    mesh = mesh or make_local_mesh()
    model = build_model(cfg)
    pipe = TokenPipeline(
        vocab=cfg.vocab, seq_len=st.seq, global_batch=st.batch, seed=st.seed
    )
    opt_init, step_fn = _build_step(model, cfg, st, mesh)

    with mesh:
        params = model.init(jax.random.PRNGKey(st.seed))
        opt_state = opt_init(params)

        start = 0
        ck = latest_step(st.ckpt_dir) if resume is None else resume
        if ck is not None:
            params = restore(st.ckpt_dir, ck, params)
            opt_state = restore(Path(st.ckpt_dir) / "opt", ck, opt_state)
            start = ck
            print(f"[train] resumed from step {ck}")

        ckpt = Checkpointer(st.ckpt_dir, st.keep_last)
        ckpt_opt = Checkpointer(Path(st.ckpt_dir) / "opt", st.keep_last)
        wd = Watchdog(Path(st.ckpt_dir) / "heartbeat.json")
        losses = []
        t0 = time.time()
        end = min(st.steps, stop_at) if stop_at is not None else st.steps
        for step in range(start, end):
            batch = jax.tree.map(jnp.asarray, pipe.batch(step))
            params, opt_state, mets = step_fn(
                params, opt_state, batch, jnp.asarray(step, jnp.int32)
            )
            loss = float(mets["loss"])
            losses.append(loss)
            wd.step(step, {"loss": loss})
            if step % st.log_every == 0 or step == st.steps - 1:
                print(f"[train] step {step}: loss {loss:.4f} lr {float(mets['lr']):.2e}")
            if (step + 1) % st.ckpt_every == 0 or step == end - 1:
                ckpt.save_async(step + 1, params)
                ckpt_opt.save_async(step + 1, opt_state)
        ckpt.wait()
        ckpt_opt.wait()
    return {
        "final_loss": losses[-1],
        "first_loss": losses[0],
        "losses": losses,
        "wall_s": time.time() - t0,
        "params": params,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="results/ckpt")
    ap.add_argument("--max-restarts", type=int, default=2)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    st = TrainSettings(
        steps=args.steps,
        batch=args.batch,
        seq=args.seq,
        lr=args.lr,
        microbatches=args.microbatches,
        ckpt_dir=args.ckpt_dir,
    )

    def run(resume):
        out = train(cfg, st, resume=resume)
        print(
            f"[train] done: loss {out['first_loss']:.4f} -> {out['final_loss']:.4f} "
            f"in {out['wall_s']:.1f}s"
        )
        return st.steps

    run_with_restart(run, max_restarts=args.max_restarts)


if __name__ == "__main__":
    main()
