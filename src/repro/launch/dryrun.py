import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes, record memory/cost analysis + roofline terms.

The two lines above MUST stay first: jax locks the device count on first
init, and the dry-run needs 512 placeholder host devices for the 2×16×16
multi-pod mesh. (Smoke tests / benches import other modules and see 1 device.)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out results/dryrun]

Results are cached per (arch, shape, mesh) in JSON; re-runs skip green cells.
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs.registry import ARCHS, for_shape, get_config
from repro.configs.shapes import SHAPES, valid_cells
from repro.launch import shardings as shmod
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell
from repro.roofline.analysis import roofline

DEFAULT_OUT = Path("results/dryrun")


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path, force=False):
    mesh_tag = "multipod" if multi_pod else "singlepod"
    out_file = out_dir / f"{arch}__{shape_name}__{mesh_tag}.json"
    if out_file.exists() and not force:
        rec = json.loads(out_file.read_text())
        if rec.get("status") == "ok":
            print(f"[cache] {arch} × {shape_name} × {mesh_tag}: ok")
            return rec

    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag}
    try:
        # scope fallback recording to THIS cell: concurrent/repeated cells no
        # longer leak replication records into each other's reports
        with shmod.record_fallbacks() as cell_fallbacks:
            mesh = make_production_mesh(multi_pod=multi_pod)
            n_dev = mesh.devices.size
            cell = build_cell(arch, shape_name, mesh)
            with mesh:
                jitted = jax.jit(
                    cell.fn,
                    in_shardings=cell.in_shardings,
                    donate_argnums=cell.donate,
                )
                lowered = jitted.lower(*cell.args)
                t_lower = time.time() - t0
                compiled = lowered.compile()
                t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        mem_stats = {}
        for k in ("argument_size_in_bytes", "output_size_in_bytes", "temp_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                mem_stats[k] = int(v)
        # resident bytes per device: sharded argument shards (weights, opt
        # state, caches, batch). CPU temp sizes are unfused-buffer artifacts,
        # reported but not representative of TPU HBM with remat.
        import numpy as np

        resident = 0
        shard_leaves = jax.tree.leaves(
            cell.in_shardings, is_leaf=lambda x: hasattr(x, "shard_shape")
        )
        for sds, shd in zip(jax.tree.leaves(cell.args), shard_leaves):
            shard = shd.shard_shape(sds.shape) if hasattr(shd, "shard_shape") else sds.shape
            resident += int(np.prod(shard)) * sds.dtype.itemsize
        mem_stats["bytes"] = resident

        cost_list = compiled.cost_analysis()
        cost = cost_list[0] if isinstance(cost_list, (list, tuple)) else cost_list
        hlo = compiled.as_text()

        rep = roofline(
            arch, SHAPES[shape_name], cell.cfg, cost, hlo, n_dev, mem_stats
        )
        rec.update(
            status="ok",
            n_devices=n_dev,
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            memory=mem_stats,
            fallbacks=list(cell_fallbacks),
            roofline=rep.to_dict(),
            roofline_fraction=rep.roofline_fraction,
            hlo_bytes=len(hlo),
        )
        print(
            f"[ok] {arch} × {shape_name} × {mesh_tag}: "
            f"compile {t_compile:.0f}s, mem/dev {resident/2**30:.2f} GiB, "
            f"t=(c {rep.t_compute*1e3:.2f} | m {rep.t_memory*1e3:.2f} | "
            f"x {rep.t_collective*1e3:.2f}) ms, bottleneck={rep.bottleneck}, "
            f"MODEL/HLO={rep.useful_ratio:.2f}"
        )
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec.update(status="fail", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        print(f"[FAIL] {arch} × {shape_name} × {mesh_tag}: {e}")

    out_dir.mkdir(parents=True, exist_ok=True)
    out_file.write_text(json.dumps(rec, indent=2, default=str))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=str(DEFAULT_OUT))
    args = ap.parse_args()
    out_dir = Path(args.out)

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    cells = []
    if args.all:
        for arch, cfg in ARCHS.items():
            for shp in valid_cells(cfg):
                cells.append((arch, shp))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    n_ok = n_fail = 0
    for multi in meshes:
        for arch, shp in cells:
            rec = run_cell(arch, shp, multi, out_dir, force=args.force)
            if rec.get("status") == "ok":
                n_ok += 1
            else:
                n_fail += 1
    print(f"\ndry-run complete: {n_ok} ok, {n_fail} failed")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
