import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf hillclimb driver: re-lower + re-analyse named variants of the three
chosen cells (EXPERIMENTS.md §Perf). Baselines live in results/dryrun.

  PYTHONPATH=src python -m repro.launch.hillclimb [--variant NAME]
"""

import argparse
import dataclasses
import json
import time
from pathlib import Path

import jax

from repro.configs.registry import for_shape, get_config
from repro.configs.shapes import SHAPES
from repro.core.cim_linear import CiMConfig
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell
from repro.roofline.analysis import roofline

OUT = Path("results/hillclimb")


def _cfg(arch, shape, **over):
    cfg = for_shape(get_config(arch), SHAPES[shape])
    return dataclasses.replace(cfg, **over) if over else cfg


# variant name -> (arch, shape, cfg_override or None)
VARIANTS = {
    # Cell A: llama3-405b train_4k — memory-bound (mixed-precision materialization)
    # A1+A2 live in the model code (rms_norm + bf16 attention scores); this
    # re-lowers the same config against the updated implementation.
    "A_llama405b_train/opt_mixed_precision": ("llama3-405b", "train_4k", {}),
    # A3: smaller attention KV chunk — fewer bytes per materialized score tile
    "A_llama405b_train/opt_chunk512": (
        "llama3-405b",
        "train_4k",
        {"attn_chunk": 512},
    ),
    # Cell B: qwen3-moe train_4k — collective-bound (dispatch elimination)
    "B_qwen3moe_train/opt_dense_moe": (
        "qwen3-moe-30b-a3b",
        "train_4k",
        {"moe_impl": "dense"},
    ),
    # B2: dense MoE + mixed precision together on the runner-up (moonshot)
    "B_moonshot_train/opt_dense_moe": (
        "moonshot-v1-16b-a3b",
        "train_4k",
        {"moe_impl": "dense"},
    ),
    # Cell C: command-r-plus decode_32k — memory-bound serving
    # C1: int8 weight/activation dots (the paper's low-precision product-sums on MXU)
    "C_commandr_decode/opt_int8_weights": (
        "command-r-plus-104b",
        "decode_32k",
        {"cim": CiMConfig(mode="int8_dot", ste=False)},
    ),
    # C2: + int8 KV cache
    "C_commandr_decode/opt_int8_weights_kv": (
        "command-r-plus-104b",
        "decode_32k",
        {"cim": CiMConfig(mode="int8_dot", ste=False), "kv_quant_int8": True},
    ),
    # C2b: int8 KV cache alone (ablation)
    "C_commandr_decode/opt_int8_kv_only": (
        "command-r-plus-104b",
        "decode_32k",
        {"kv_quant_int8": True},
    ),
}


def run_variant(name: str, force: bool = False):
    arch, shape_name, over = VARIANTS[name]
    out_file = OUT / (name.replace("/", "__") + ".json")
    if out_file.exists() and not force:
        rec = json.loads(out_file.read_text())
        if rec.get("status") == "ok":
            print(f"[cache] {name}")
            return rec
    t0 = time.time()
    rec = {"variant": name, "arch": arch, "shape": shape_name}
    try:
        mesh = make_production_mesh()
        cfg = _cfg(arch, shape_name, **over)
        cell = build_cell(arch, shape_name, mesh, cfg_override=cfg)
        with mesh:
            compiled = (
                jax.jit(cell.fn, in_shardings=cell.in_shardings, donate_argnums=cell.donate)
                .lower(*cell.args)
                .compile()
            )
        import numpy as np

        resident = 0
        shard_leaves = jax.tree.leaves(
            cell.in_shardings, is_leaf=lambda x: hasattr(x, "shard_shape")
        )
        for sds, shd in zip(jax.tree.leaves(cell.args), shard_leaves):
            shard = shd.shard_shape(sds.shape) if hasattr(shd, "shard_shape") else sds.shape
            resident += int(np.prod(shard)) * sds.dtype.itemsize

        rep = roofline(
            arch, SHAPES[shape_name], cell.cfg, {}, compiled.as_text(),
            mesh.devices.size, {"bytes": resident},
        )
        rec.update(
            status="ok",
            compile_s=round(time.time() - t0, 1),
            memory={"bytes": resident},
            roofline=rep.to_dict(),
            roofline_fraction=rep.roofline_fraction,
        )
        print(
            f"[ok] {name}: t=(c {rep.t_compute:.2f} | m {rep.t_memory:.2f} | "
            f"x {rep.t_collective:.2f}) s, mem/dev {resident/2**30:.2f} GiB, "
            f"bottleneck={rep.bottleneck}, frac={rep.roofline_fraction:.4f}"
        )
    except Exception as e:  # noqa: BLE001
        import traceback

        rec.update(status="fail", error=str(e), traceback=traceback.format_exc()[-3000:])
        print(f"[FAIL] {name}: {e}")
    OUT.mkdir(parents=True, exist_ok=True)
    out_file.write_text(json.dumps(rec, indent=2, default=str))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", default=None)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    names = [args.variant] if args.variant else list(VARIANTS)
    for n in names:
        run_variant(n, force=args.force)


if __name__ == "__main__":
    main()
