"""Pallas TPU kernels (interpret=True validated on CPU) + jnp oracles."""

from repro.kernels.ops import adc_quant_op, cim_matmul_op

__all__ = ["adc_quant_op", "cim_matmul_op"]
