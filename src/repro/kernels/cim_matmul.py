"""Fused CiM matmul Pallas TPU kernel.

TPU-native adaptation of the paper's memory-immersed digitization: the
reduction dimension is tiled into ``rows``-sized "CiM arrays"; each row-tile's
partial product-sum (the MAV) is digitized *inside the kernel* — in VMEM,
next to the compute, exactly as the paper's digitizer lives inside the memory
fabric — before digital recombination into the output accumulator.

Two modes (static):
  * ``fake_quant`` — per-row-tile partial sums quantized with the
    RMS-equivalent composite step (1 MXU matmul per row-tile).
  * ``bitplane``   — faithful A×W bit-plane decomposition in-register, one MXU
    matmul per plane pair per row-tile, ideal B-bit ADC per MAV.

Grid: (M/bm, N/bn, K/bk), K innermost; the output block accumulates across K
steps. Block shapes default to MXU-aligned 128 multiples; ``bk`` must be a
multiple of ``rows``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["cim_matmul_pallas", "adc_quant_pallas"]


def _quantize_tile(partial: jnp.ndarray, step: float) -> jnp.ndarray:
    # round-half-away-from-zero to match jnp.round on .5 boundaries is not
    # needed: jnp.round is round-half-even in both kernel and oracle.
    return jnp.round(partial / step) * step


def _cim_matmul_kernel_fakequant(x_ref, w_ref, o_ref, *, rows, step, n_k):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    bm = x_ref.shape[0]
    bk = x_ref.shape[1]
    bn = w_ref.shape[1]
    acc = jnp.zeros((bm, bn), jnp.float32)
    for t in range(bk // rows):  # static unroll over row-tiles ("CiM arrays")
        xs = x_ref[:, t * rows : (t + 1) * rows]
        ws = w_ref[t * rows : (t + 1) * rows, :]
        partial = jnp.dot(xs, ws, preferred_element_type=jnp.float32)
        acc = acc + _quantize_tile(partial, step)
    o_ref[...] += acc


def _cim_matmul_kernel_bitplane(
    x_ref, w_ref, o_ref, *, rows, adc_bits, a_bits, w_bits, a_signed, w_signed, n_k
):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    bm = x_ref.shape[0]
    bk = x_ref.shape[1]
    bn = w_ref.shape[1]
    n_codes = 1 << adc_bits

    xi = x_ref[...]
    wi = w_ref[...]
    if a_signed:
        xi = jnp.where(xi < 0, xi + (1 << a_bits), xi)
    if w_signed:
        wi = jnp.where(wi < 0, wi + (1 << w_bits), wi)

    acc = jnp.zeros((bm, bn), jnp.float32)
    for t in range(bk // rows):
        xs = xi[:, t * rows : (t + 1) * rows]
        ws = wi[t * rows : (t + 1) * rows, :]
        for a in range(a_bits):
            sa = -(1 << a) if (a_signed and a == a_bits - 1) else (1 << a)
            xp = ((xs >> a) & 1).astype(jnp.float32)
            for b in range(w_bits):
                sb = -(1 << b) if (w_signed and b == w_bits - 1) else (1 << b)
                wp = ((ws >> b) & 1).astype(jnp.float32)
                mav = jnp.dot(xp, wp, preferred_element_type=jnp.float32) / rows
                codes = jnp.clip(jnp.floor(mav * n_codes), 0, n_codes - 1)
                counts = codes / n_codes * rows  # floor reconstruction
                acc = acc + float(sa * sb) * counts
    o_ref[...] += acc


@functools.partial(
    jax.jit,
    static_argnames=(
        "rows",
        "adc_bits",
        "mode",
        "a_bits",
        "w_bits",
        "a_signed",
        "w_signed",
        "block_m",
        "block_n",
        "block_k",
        "interpret",
    ),
)
def cim_matmul_pallas(
    x_int: jnp.ndarray,  # (M, K) float32 int-valued (fake_quant) / int32 (bitplane)
    w_int: jnp.ndarray,  # (K, N) same dtype
    *,
    rows: int = 128,
    adc_bits: int = 8,
    mode: str = "fake_quant",
    a_bits: int = 8,
    w_bits: int = 8,
    a_signed: bool = True,
    w_signed: bool = True,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused CiM matmul. M, N, K must be multiples of the block shapes
    (``ops.py`` pads); ``block_k`` must be a multiple of ``rows``."""
    m, k = x_int.shape
    n = w_int.shape[1]
    if block_k % rows:
        raise ValueError("block_k must be a multiple of rows")
    if m % block_m or n % block_n or k % block_k:
        raise ValueError("unpadded shapes; use repro.kernels.ops wrappers")
    n_k = k // block_k

    if mode == "fake_quant":
        from repro.kernels.ref import fake_quant_step

        step = fake_quant_step(rows, adc_bits, a_bits, w_bits, a_signed, w_signed)
        kernel = functools.partial(
            _cim_matmul_kernel_fakequant, rows=rows, step=step, n_k=n_k
        )
    elif mode == "bitplane":
        kernel = functools.partial(
            _cim_matmul_kernel_bitplane,
            rows=rows,
            adc_bits=adc_bits,
            a_bits=a_bits,
            w_bits=w_bits,
            a_signed=a_signed,
            w_signed=w_signed,
            n_k=n_k,
        )
    else:
        raise ValueError(f"unknown mode {mode!r}")

    return pl.pallas_call(
        kernel,
        grid=(m // block_m, n // block_n, n_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x_int, w_int)


# ---------------------------------------------------------------------------
# Standalone tiled ADC quantization kernel
# ---------------------------------------------------------------------------


def _adc_quant_kernel(v_ref, o_ref, *, bits, vdd):
    n = 1 << bits
    v = v_ref[...]
    codes = jnp.clip(jnp.floor(v / vdd * n), 0, n - 1)
    o_ref[...] = (codes + 0.5) * (vdd / n)


@functools.partial(
    jax.jit, static_argnames=("bits", "vdd", "block_m", "block_n", "interpret")
)
def adc_quant_pallas(
    v: jnp.ndarray,  # (M, N) float32 analog values
    *,
    bits: int = 5,
    vdd: float = 1.0,
    block_m: int = 256,
    block_n: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    m, n = v.shape
    if m % block_m or n % block_n:
        raise ValueError("unpadded shapes; use repro.kernels.ops wrappers")
    return pl.pallas_call(
        functools.partial(_adc_quant_kernel, bits=bits, vdd=vdd),
        grid=(m // block_m, n // block_n),
        in_specs=[pl.BlockSpec((block_m, block_n), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(v)
