"""Pure-jnp oracles for the Pallas kernels (bit-exact contracts).

The kernels operate on *pre-quantized integer-valued* tensors (quantization
scales are applied by the ``ops.py`` wrappers), so the oracle contracts are
exact integer/fixed-point math with no RNG:

  * ``adc_quant_ref``   — ideal B-bit staircase over a voltage tile.
  * ``cim_matmul_ref``  — tiled CiM matmul, ``fake_quant`` or ``bitplane``
                          semantics, matching ``core.cim_linear`` with an
                          ideal (noiseless) ADC.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cim_array import plane_weights

__all__ = ["adc_quant_ref", "cim_matmul_ref", "fake_quant_step", "flash_attention_ref"]


def adc_quant_ref(v: jnp.ndarray, bits: int, vdd: float = 1.0) -> jnp.ndarray:
    """Ideal mid-tread ADC + mid-point reconstruction: v -> v_hat."""
    n = 1 << bits
    codes = jnp.clip(jnp.floor(v / vdd * n), 0, n - 1)
    return (codes + 0.5) * (vdd / n)


def fake_quant_step(
    rows: int, adc_bits: int, a_bits: int, w_bits: int, a_signed: bool, w_signed: bool
) -> float:
    """RMS-equivalent composite quantizer step (see core.cim_linear)."""
    wa = plane_weights(a_bits, a_signed)
    ww = plane_weights(w_bits, w_signed)
    rms = float(np.sqrt((wa**2).sum()) * np.sqrt((ww**2).sum()))
    return (rows / (1 << adc_bits)) * rms


def cim_matmul_ref(
    x_int: jnp.ndarray,  # (M, K) float32, integer-valued
    w_int: jnp.ndarray,  # (K, N) float32, integer-valued
    *,
    rows: int = 128,
    adc_bits: int = 8,
    mode: str = "fake_quant",
    a_bits: int = 8,
    w_bits: int = 8,
    a_signed: bool = True,
    w_signed: bool = True,
    exact_counts: bool = False,
) -> jnp.ndarray:
    """Oracle for the fused CiM matmul kernel. K must divide by ``rows``."""
    m, k = x_int.shape
    n = w_int.shape[1]
    assert k % rows == 0, "wrapper pads K to a multiple of rows"
    t = k // rows

    if mode == "fake_quant":
        xt = x_int.reshape(m, t, rows)
        wt = w_int.reshape(t, rows, n)
        partial = jnp.einsum("mtr,trn->mtn", xt, wt)
        step = fake_quant_step(rows, adc_bits, a_bits, w_bits, a_signed, w_signed)
        return (jnp.round(partial / step) * step).sum(axis=1)

    if mode == "bitplane":
        n_codes = 1 << adc_bits
        wa = plane_weights(a_bits, a_signed)
        ww = plane_weights(w_bits, w_signed)
        xi = x_int.astype(jnp.int32)
        wi = w_int.astype(jnp.int32)
        if a_signed:
            xi = jnp.where(xi < 0, xi + (1 << a_bits), xi)
        if w_signed:
            wi = jnp.where(wi < 0, wi + (1 << w_bits), wi)
        y = jnp.zeros((m, n), jnp.float32)
        for a in range(a_bits):
            xp = ((xi >> a) & 1).astype(jnp.float32).reshape(m, t, rows)
            for b in range(w_bits):
                wp = ((wi >> b) & 1).astype(jnp.float32).reshape(t, rows, n)
                mav = jnp.einsum("mtr,trn->mtn", xp, wp) / rows
                codes = jnp.clip(jnp.floor(mav * n_codes), 0, n_codes - 1)
                counts = codes / n_codes * rows  # floor reconstruction
                if exact_counts:
                    counts = jnp.round(counts)
                y = y + float(wa[a] * ww[b]) * counts.sum(axis=1)
        return y

    raise ValueError(f"unknown mode {mode!r}")


def flash_attention_ref(q, k, v, *, causal=True, sm_scale=None):
    """Plain softmax attention oracle (GQA): q (B,H,Sq,hd), k/v (B,KV,Sk,hd)."""
    b, h, sq, hd = q.shape
    kv, sk = k.shape[1], k.shape[2]
    g = h // kv
    if sm_scale is None:
        sm_scale = hd ** -0.5
    qf = q.astype(jnp.float32).reshape(b, kv, g, sq, hd) * sm_scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgqd,bkcd->bkgqc", qf, kf)
    if causal:
        mask = jnp.arange(sk)[None, :] <= jnp.arange(sq)[:, None]
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqc,bkcd->bkgqd", p, vf)
    return o.reshape(b, h, sq, hd).astype(q.dtype)
