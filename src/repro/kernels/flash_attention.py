"""Fused causal flash-attention Pallas TPU kernel (GQA-aware).

The §Perf analysis shows dense-LM train/prefill cells are bound by attention
score tiles round-tripping HBM (EXPERIMENTS.md). This kernel keeps the whole
online-softmax pipeline in VMEM — q tiles stream against a VMEM-resident K/V
(per (batch, head) grid cell), score/probability tiles never materialize in
HBM, and causal masking SKIPS fully-masked KV blocks (the dynamic
``fori_loop`` bound), halving attention FLOPs vs the masked-dense scan.

Sequence parallelism: ``q_positions`` carries ABSOLUTE query positions, so a
q-sequence shard (inside shard_map, each tp rank owning S/tp query rows
against the full K/V) masks correctly — this is how launch-time prefill uses
it (models/layers._flash_sharded, perf iteration D).

Scope: Sk·hd·bf16 K/V per (batch, head) must fit VMEM (32k×128 = 8 MiB ✓).
Validated in interpret mode against ``ref.flash_attention_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

__all__ = ["flash_attention_pallas"]

_NEG = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, qpos_ref, o_ref, *, sm_scale, block_q, block_k, causal
):
    q = q_ref[0, 0].astype(jnp.float32) * sm_scale  # (bq, hd)
    sk = k_ref.shape[2]
    n_kv = sk // block_k
    q_pos = qpos_ref[...].reshape(block_q, 1)  # absolute positions

    if causal:
        # highest kv block intersecting this q tile's causal triangle
        upper = jnp.minimum(jnp.max(q_pos) // block_k + 1, n_kv)
    else:
        upper = n_kv

    hd = k_ref.shape[3]

    def body(j, carry):
        m, l, acc = carry
        # every index a Slice: bare ints in the tuple break interpret-mode
        # discharge (jax state_discharge expects .shape on non-Slice indices)
        idx = (
            pl.dslice(0, 1),
            pl.dslice(0, 1),
            pl.dslice(j * block_k, block_k),
            pl.dslice(0, hd),
        )
        k = pl.load(k_ref, idx)[0, 0].astype(jnp.float32)
        v = pl.load(v_ref, idx)[0, 0].astype(jnp.float32)
        s = q @ k.T  # (bq, bk)
        if causal:
            k_pos = j * block_k + lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
            mask = k_pos <= q_pos
            s = jnp.where(mask, s, _NEG)
        m_new = jnp.maximum(m, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        if causal:
            p = p * mask.astype(jnp.float32)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(axis=-1, keepdims=True)
        acc = acc * alpha + p @ v
        return m_new, l, acc

    m0 = jnp.full((block_q, 1), _NEG, jnp.float32)
    l0 = jnp.zeros((block_q, 1), jnp.float32)
    a0 = jnp.zeros((block_q, q.shape[-1]), jnp.float32)
    m, l, acc = lax.fori_loop(0, upper, body, (m0, l0, a0))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "sm_scale", "block_q", "block_k", "interpret"),
)
def flash_attention_pallas(
    q: jnp.ndarray,  # (B, H, Sq, hd)
    k: jnp.ndarray,  # (B, KV, Sk, hd)  KV divides H (GQA)
    v: jnp.ndarray,  # (B, KV, Sk, hd)
    q_positions: jnp.ndarray | None = None,  # (Sq,) absolute; default arange
    *,
    causal: bool = True,
    sm_scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    b, h, sq, hd = q.shape
    kv, sk = k.shape[1], k.shape[2]
    if h % kv:
        raise ValueError("n_heads must be a multiple of n_kv_heads")
    if sq % block_q or sk % block_k:
        raise ValueError("pad Sq/Sk to block multiples")
    g = h // kv
    if sm_scale is None:
        sm_scale = hd ** -0.5
    if q_positions is None:
        q_positions = jnp.arange(sq, dtype=jnp.int32)

    kernel = functools.partial(
        _flash_kernel,
        sm_scale=sm_scale,
        block_q=block_q,
        block_k=block_k,
        causal=causal,
    )
    return pl.pallas_call(
        kernel,
        grid=(b, h, sq // block_q),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda bb, hh, ii: (bb, hh, ii, 0)),
            pl.BlockSpec((1, 1, sk, hd), lambda bb, hh, ii: (bb, hh // g, 0, 0)),
            pl.BlockSpec((1, 1, sk, hd), lambda bb, hh, ii: (bb, hh // g, 0, 0)),
            pl.BlockSpec((block_q,), lambda bb, hh, ii: (ii,)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda bb, hh, ii: (bb, hh, ii, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, hd), q.dtype),
        interpret=interpret,
    )(q, k, v, q_positions.astype(jnp.int32))
