"""Public jit'd wrappers for the Pallas kernels: padding, quantization,
scale handling, and CPU interpret-mode fallback.

``cim_matmul_op(x, w, ...)`` is the drop-in accelerated counterpart of
``core.cim_linear.cim_matmul`` with an ideal (noiseless) ADC.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.cim_linear import quantize_symmetric
from repro.kernels.cim_matmul import adc_quant_pallas, cim_matmul_pallas

__all__ = ["cim_matmul_op", "adc_quant_op"]


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jnp.ndarray, mults: tuple[int, ...]) -> jnp.ndarray:
    pads = [(0, (-s) % m) for s, m in zip(x.shape, mults)]
    if any(p[1] for p in pads):
        return jnp.pad(x, pads)
    return x


@functools.partial(
    jax.jit,
    static_argnames=(
        "rows",
        "adc_bits",
        "mode",
        "a_bits",
        "w_bits",
        "a_signed",
        "w_signed",
        "block_m",
        "block_n",
        "block_k",
        "interpret",
    ),
)
def cim_matmul_op(
    x: jnp.ndarray,  # (..., K) float
    w: jnp.ndarray,  # (K, N) float
    *,
    rows: int = 128,
    adc_bits: int = 8,
    mode: str = "fake_quant",
    a_bits: int = 8,
    w_bits: int = 8,
    a_signed: bool = True,
    w_signed: bool = True,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """CiM-quantized ``x @ w`` on the fused Pallas kernel."""
    if interpret is None:
        interpret = _default_interpret()
    if block_k is None:
        block_k = max(rows, 512 - 512 % rows) if rows <= 512 else rows

    batch_shape = x.shape[:-1]
    k = x.shape[-1]
    n = w.shape[1]
    xm = x.reshape(-1, k)
    m = xm.shape[0]

    x_int, sx = quantize_symmetric(xm, a_bits, a_signed)
    w_int, sw = quantize_symmetric(w, w_bits, w_signed, per_axis=-1)

    dt = jnp.int32 if mode == "bitplane" else jnp.float32
    xp = _pad_to(x_int.astype(dt), (block_m, block_k))
    wp = _pad_to(w_int.astype(dt), (block_k, block_n))

    y = cim_matmul_pallas(
        xp,
        wp,
        rows=rows,
        adc_bits=adc_bits,
        mode=mode,
        a_bits=a_bits,
        w_bits=w_bits,
        a_signed=a_signed,
        w_signed=w_signed,
        block_m=block_m,
        block_n=block_n,
        block_k=block_k,
        interpret=interpret,
    )[:m, :n]
    y = y * sx * sw
    return y.reshape(*batch_shape, n)


@functools.partial(
    jax.jit, static_argnames=("bits", "vdd", "block_m", "block_n", "interpret")
)
def adc_quant_op(
    v: jnp.ndarray,
    *,
    bits: int = 5,
    vdd: float = 1.0,
    block_m: int = 256,
    block_n: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Tiled ideal-ADC quantize+reconstruct of a 2D analog-value array."""
    if interpret is None:
        interpret = _default_interpret()
    m, n = v.shape
    bm, bn = min(block_m, max(m, 8)), min(block_n, max(n, 128))
    vp = _pad_to(v, (bm, bn))
    out = adc_quant_pallas(
        vp, bits=bits, vdd=vdd, block_m=bm, block_n=bn, interpret=interpret
    )
    return out[:m, :n]
