"""Atomic, async, elastic checkpointing."""

from repro.checkpoint.ckpt import Checkpointer, latest_step, restore, save

__all__ = ["Checkpointer", "latest_step", "restore", "save"]
