"""Sharded, atomic, elastic checkpointing (no orbax offline).

Layout of one checkpoint:
  <dir>/step_000123/
    manifest.json        — step, flat keys, shapes, dtypes, mesh info
    arrays.npz           — one entry per flattened-path leaf

Properties:
  * atomic: written to ``step_X.tmp`` then renamed — a crash mid-save never
    corrupts the latest checkpoint (fault-tolerance requirement).
  * keep_last k garbage collection.
  * async: ``save_async`` hands the host copy to a writer thread so the train
    loop overlaps checkpoint I/O with compute.
  * elastic: arrays are stored as full (host-gathered) logical arrays with
    their global shapes; ``restore`` re-device_puts them under ANY mesh and
    sharding — restart on a different pod count just works. (At >10k-chip
    scale you would save per-host shards; the manifest already records the
    global shape + dtype needed for that extension.)
"""

from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save", "save_async", "restore", "latest_step", "Checkpointer"]


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p))
            for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def save(ckpt_dir: str | Path, step: int, tree: Any, keep_last: int = 3) -> Path:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:09d}"
    tmp = ckpt_dir / f"step_{step:09d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    flat = _flatten(tree)
    manifest = {
        "step": step,
        "keys": {k: {"shape": list(v.shape), "dtype": str(v.dtype)} for k, v in flat.items()},
    }
    np.savez(tmp / "arrays.npz", **flat)
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic publish

    # GC old checkpoints
    steps = sorted(p for p in ckpt_dir.glob("step_*") if not p.name.endswith(".tmp"))
    for old in steps[:-keep_last]:
        shutil.rmtree(old, ignore_errors=True)
    return final


class Checkpointer:
    """Async wrapper: snapshot to host, write in a background thread."""

    def __init__(self, ckpt_dir: str | Path, keep_last: int = 3):
        self.dir = Path(ckpt_dir)
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save_async(self, step: int, tree: Any):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # host snapshot now

        def _write():
            save(self.dir, step, host_tree, self.keep_last)

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()


def save_async(ckpt_dir, step, tree, keep_last: int = 3) -> Checkpointer:
    c = Checkpointer(ckpt_dir, keep_last)
    c.save_async(step, tree)
    return c


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = sorted(
        int(p.name.split("_")[1]) for p in ckpt_dir.glob("step_*") if not p.name.endswith(".tmp")
    )
    return steps[-1] if steps else None


def restore(
    ckpt_dir: str | Path,
    step: int,
    like: Any,
    shardings: Any = None,
):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs), placing each leaf with the matching sharding —
    elastic across mesh changes."""
    path = Path(ckpt_dir) / f"step_{step:09d}"
    data = np.load(path / "arrays.npz")
    flat_like = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    sh_leaves = (
        jax.tree.leaves(shardings, is_leaf=lambda x: hasattr(x, "shard_shape"))
        if shardings is not None
        else [None] * len(flat_like[0])
    )
    for (pth, leaf), shd in zip(flat_like[0], sh_leaves):
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p)) for p in pth
        )
        arr = data[key]
        if shd is not None:
            arr = jax.device_put(arr, shd)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(flat_like[1], leaves)
