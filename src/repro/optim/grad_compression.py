"""Int8 gradient compression for the data-parallel all-reduce.

At 1000+-node scale the DP gradient all-reduce dominates the interconnect;
quantizing gradients to int8 with per-leaf scales cuts the wire bytes 4x
(vs f32) / 2x (vs bf16). Implemented as a quantize -> psum(int32) -> dequant
wrapper usable inside ``shard_map``; an error-feedback buffer keeps the
compression unbiased over steps (residual is re-added next step).

``compressed_psum_tree`` is wired into the train step behind
``TrainSettings.grad_compression`` (launch/train.py); wire-byte accounting
for the roofline lives in roofline/analysis.py.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "compressed_psum_tree", "init_error_feedback"]


def quantize_int8(g: jnp.ndarray):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray):
    return q.astype(jnp.float32) * scale


def init_error_feedback(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum_tree(grads, axis_name: str, error_feedback=None):
    """Per-leaf int8 quantization + psum over ``axis_name``.

    Returns (mean-reduced grads, new error feedback). Call inside shard_map /
    pjit with a named axis. The int32 psum models the int8 ring-reduce wire
    format (accumulation must widen to avoid overflow at >127 summands).
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        g32 = g.astype(jnp.float32) + (e if e is not None else 0.0)
        # shared scale so the int8 payloads are summable across devices
        scale = jax.lax.pmax(jnp.max(jnp.abs(g32)), axis_name) / 127.0
        scale = jnp.maximum(scale, 1e-12)
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        resid = g32 - q.astype(jnp.float32) * scale  # error feedback
        tot = jax.lax.psum(q.astype(jnp.int32), axis_name)
        out = tot.astype(jnp.float32) * scale / n
        return out, resid

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(error_feedback) if error_feedback is not None else [None] * len(flat_g)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return tdef.unflatten([o[0] for o in outs]), tdef.unflatten([o[1] for o in outs])
