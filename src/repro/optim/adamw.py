"""AdamW on pytrees (no optax offline) — state shards like the params."""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWState", "adamw_init", "adamw_update"]


class AdamWState(NamedTuple):
    m: Any
    v: Any
    count: jnp.ndarray


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
        count=jnp.zeros((), jnp.int32),
    )


def adamw_update(
    grads,
    state: AdamWState,
    params,
    lr,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: float = 1.0,
):
    count = state.count + 1
    if grad_clip is not None:
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
        )
        scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    else:
        gnorm = jnp.zeros(())

    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32), state.m, grads)
    v = jax.tree.map(
        lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)), state.v, grads
    )
    c1 = 1 - b1 ** count.astype(jnp.float32)
    c2 = 1 - b2 ** count.astype(jnp.float32)

    def upd(p, m_, v_):
        step = (m_ / c1) / (jnp.sqrt(v_ / c2) + eps)
        return (p.astype(jnp.float32) - lr * (step + weight_decay * p.astype(jnp.float32))).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, AdamWState(m=m, v=v, count=count), {"grad_norm": gnorm}
