"""Optimizers (AdamW, Adafactor), LR schedules, gradient compression."""

from repro.optim.adafactor import AdafactorState, adafactor_init, adafactor_update
from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.optim.schedules import warmup_cosine, warmup_linear


def make_optimizer(name: str):
    """Returns (init_fn, update_fn) for the configured optimizer."""
    if name == "adamw":
        return adamw_init, adamw_update
    if name == "adafactor":
        return adafactor_init, adafactor_update
    raise ValueError(f"unknown optimizer {name!r}")


__all__ = [
    "AdamWState",
    "adamw_init",
    "adamw_update",
    "AdafactorState",
    "adafactor_init",
    "adafactor_update",
    "warmup_cosine",
    "warmup_linear",
    "make_optimizer",
]
