"""Adafactor (factored second moments, no momentum) — O(rows+cols) state.

Used for the 405B-scale configs where Adam's 8 bytes/param of optimizer state
would not fit the single-pod HBM budget (see EXPERIMENTS.md §Dry-run).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdafactorState", "adafactor_init", "adafactor_update"]


class AdafactorState(NamedTuple):
    v_row: Any  # factored stats for >=2D leaves (zeros-shaped otherwise)
    v_col: Any
    v_full: Any  # full stats for <2D leaves
    count: jnp.ndarray


def _factored(p) -> bool:
    return p.ndim >= 2


def adafactor_init(params) -> AdafactorState:
    def vr(p):
        return jnp.zeros(p.shape[:-1], jnp.float32) if _factored(p) else jnp.zeros((1,), jnp.float32)

    def vc(p):
        return (
            jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            if _factored(p)
            else jnp.zeros((1,), jnp.float32)
        )

    def vf(p):
        return jnp.zeros((1,), jnp.float32) if _factored(p) else jnp.zeros(p.shape, jnp.float32)

    return AdafactorState(
        v_row=jax.tree.map(vr, params),
        v_col=jax.tree.map(vc, params),
        v_full=jax.tree.map(vf, params),
        count=jnp.zeros((), jnp.int32),
    )


def adafactor_update(
    grads,
    state: AdafactorState,
    params,
    lr,
    decay: float = 0.99,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
):
    count = state.count + 1

    def upd(p, g, vr, vc, vf):
        g = g.astype(jnp.float32)
        g2 = jnp.square(g) + eps
        if _factored(p):
            vr = decay * vr + (1 - decay) * g2.mean(axis=-1)
            vc = decay * vc + (1 - decay) * g2.mean(axis=-2)
            # v_hat = (vr ⊗ vc) / mean(vr)  (Shazeer & Stern, 2018)
            denom = (
                jnp.sqrt(vr)[..., None]
                * jnp.sqrt(vc)[..., None, :]
                * jax.lax.rsqrt(jnp.maximum(vr.mean(axis=-1, keepdims=True), eps))[..., None]
            )
            u = g / jnp.maximum(denom, eps)
        else:
            vf = decay * vf + (1 - decay) * g2
            u = g * jax.lax.rsqrt(vf)
        rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
        u = u / jnp.maximum(1.0, rms_u / clip_threshold)
        newp = p.astype(jnp.float32) - lr * (u + weight_decay * p.astype(jnp.float32))
        return newp.astype(p.dtype), vr, vc, vf

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_vr = tdef.flatten_up_to(state.v_row)
    flat_vc = tdef.flatten_up_to(state.v_col)
    flat_vf = tdef.flatten_up_to(state.v_full)
    outs = [upd(p, g, vr, vc, vf) for p, g, vr, vc, vf in zip(flat_p, flat_g, flat_vr, flat_vc, flat_vf)]
    new_params = tdef.unflatten([o[0] for o in outs])
    new_state = AdafactorState(
        v_row=tdef.unflatten([o[1] for o in outs]),
        v_col=tdef.unflatten([o[2] for o in outs]),
        v_full=tdef.unflatten([o[3] for o in outs]),
        count=count,
    )
    return new_params, new_state, {"grad_norm": jnp.zeros(())}
