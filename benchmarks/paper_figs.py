"""One benchmark per paper table/figure. Each returns CSV rows
(name, us_per_call, derived)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, n=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n * 1e6, out


def table1_adc_area_energy():
    """Paper Table I: area/energy of 5-bit conversion, 3 ADC styles."""
    from repro.core.energy_area import table1

    t = table1()
    rows = []
    for style, d in t.items():
        rows.append(
            (
                f"table1/{style}",
                0.0,
                f"tech={d['tech']};area_um2={d['area_um2']};energy_pj={d['energy_pj']}",
            )
        )
    a = t["sar"]["area_um2"] / t["in_memory"]["area_um2"]
    f = t["flash"]["area_um2"] / t["in_memory"]["area_um2"]
    ea = t["sar"]["energy_pj"] / t["in_memory"]["energy_pj"]
    ef = t["flash"]["energy_pj"] / t["in_memory"]["energy_pj"]
    rows.append(
        (
            "table1/ratios",
            0.0,
            f"area_vs_sar={a:.1f}x(paper~25x);area_vs_flash={f:.1f}x(paper~51x);"
            f"energy_vs_sar={ea:.2f}x(paper~1.4x);energy_vs_flash={ef:.1f}x(paper~13x)",
        )
    )
    return rows


def fig4_asymmetric_search():
    """Fig. 4: MAV skew + expected comparisons, symmetric vs asymmetric."""
    from repro.core import search_tree as st
    from repro.core.adc import ADCConfig, convert
    from repro.core.mav_stats import analytic_code_pmf, analytic_mav_pmf, entropy_bits

    rows = []
    pmf_mav = analytic_mav_pmf(16, 0.25)
    rows.append(
        (
            "fig4a/mav_distribution",
            0.0,
            f"mode_at={int(np.argmax(pmf_mav))}/16;p_discharge=0.25;"
            f"entropy_bits={entropy_bits(pmf_mav):.2f}",
        )
    )
    for bits in (3, 4, 5, 6, 7):
        pmf = analytic_code_pmf(16, bits)
        opt = st.optimal_tree(pmf)
        e = opt.expected_depth(pmf)
        rows.append(
            (
                f"fig4c/bits{bits}",
                0.0,
                f"sym={bits};asym={e:.2f};saving={100 * (1 - e / bits):.0f}%",
            )
        )
    # measured (monte-carlo) comparison count through the actual converter
    pmf = analytic_code_pmf(16, 5)
    tree = st.optimal_tree(pmf)
    v = jnp.asarray(np.random.default_rng(0).binomial(16, 0.25, 100_000) / 16.0)
    cfg = ADCConfig(bits=5, mode="sar_asym")
    us, res = _time(lambda v: convert(v, cfg, tree=tree).comparisons, v)
    rows.append(
        (
            "fig4c/measured_5bit",
            us,
            f"avg_comparisons={float(res.mean()):.3f};paper=3.7",
        )
    )
    return rows


def fig6_nonlinearity():
    """Fig. 6: staircase + DNL/INL Monte Carlo under cap mismatch."""
    from repro.core import adc

    cfg = adc.ADCConfig(bits=5, mode="sar", ref_mismatch_sigma=0.01)
    worst_dnl, worst_inl = [], []
    t0 = time.perf_counter()
    for seed in range(8):
        r, codes = adc.measure_transfer(cfg, key=jax.random.PRNGKey(seed), n_points=1 << 13)
        dnl, inl = adc.dnl_inl(r, codes, cfg)
        worst_dnl.append(np.nanmax(np.abs(dnl)))
        worst_inl.append(np.nanmax(np.abs(inl)))
    us = (time.perf_counter() - t0) / 8 * 1e6
    return [
        (
            "fig6/dnl_inl",
            us,
            f"max_dnl={max(worst_dnl):.3f}LSB;max_inl={max(worst_inl):.3f}LSB;paper<0.5",
        )
    ]


def fig7_design_space():
    """Fig. 7a,b: area & latency vs precision per ADC style."""
    from repro.core.energy_area import ADC_STYLES, area_um2, energy_pj, latency_cycles

    rows = []
    for style in ADC_STYLES:
        for bits in (3, 5, 7):
            rows.append(
                (
                    f"fig7ab/{style}/bits{bits}",
                    0.0,
                    f"area_um2={area_um2(style, bits):.1f};"
                    f"latency_cyc={latency_cycles(style, bits):.2f};"
                    f"energy_pj={energy_pj(style, bits):.1f}",
                )
            )
    return rows


def fig7_mnist(trained=None):
    """Fig. 7c,d: MNIST accuracy & ADC power vs clock frequency and VDD."""
    from repro.core.cim_linear import CiMConfig
    from repro.core.noise import AnalogEnv, power_uw
    from repro.train.mnist_mlp import evaluate, train_mlp

    if trained is None:
        params, float_acc = train_mlp(epochs=4)
    else:
        params, float_acc = trained
    cim = CiMConfig(
        mode="bitplane", a_bits=4, w_bits=4, adc_bits=5, rows=16,
        a_signed=False, ste=False,
    )
    rows = [("fig7/float_acc", 0.0, f"acc={float_acc:.3f}")]
    for f in (10e6, 25e6, 50e6, 75e6, 100e6):
        env = AnalogEnv(freq_hz=f)
        t0 = time.perf_counter()
        acc = evaluate(params, cim, env=env, n_eval=512)
        us = (time.perf_counter() - t0) * 1e6
        rows.append(
            (
                f"fig7c/freq{int(f/1e6)}MHz",
                us,
                f"acc={acc:.3f};power_uw={power_uw(env, 5):.2f}",
            )
        )
    for v in (1.0, 0.9, 0.8, 0.7, 0.6):
        env = AnalogEnv(vdd=v)
        acc = evaluate(params, cim, env=env, n_eval=512)
        rows.append(
            (
                f"fig7d/vdd{v:.1f}",
                0.0,
                f"acc={acc:.3f};power_uw={power_uw(env, 5):.2f}",
            )
        )
    return rows


def fig3_hybrid_schedule():
    """Fig. 3/5c: hybrid Flash+SAR timeline + system throughput."""
    from repro.core.schedule import hybrid_schedule, pair_sar_schedule, throughput_summary

    s = hybrid_schedule(bits=5, flash_bits=2, n_cim_arrays=3)
    p = pair_sar_schedule(bits=5, n_conversions=8)
    t = throughput_summary()
    return [
        (
            "fig3/hybrid_timeline",
            0.0,
            f"cycles={s.n_cycles};conversions={s.n_conversions};arrays={s.n_arrays}",
        ),
        (
            "fig2/pair_sar",
            0.0,
            f"conv_per_cycle_per_array={p.conversions_per_cycle_per_array:.3f}",
        ),
        (
            "system/area_throughput_gain",
            0.0,
            f"conversions_per_area_gain={t['conversions_per_area_gain']:.1f}x",
        ),
    ]
