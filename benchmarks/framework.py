"""Framework benchmarks: kernel throughput + end-to-end step timings (CPU
container; TPU numbers come from the dry-run roofline in EXPERIMENTS.md)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, n=3, warmup=1):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n * 1e6, out


def bench_cim_kernels():
    """cim_matmul / adc_quant Pallas kernels (interpret) vs jnp oracle."""
    from repro.kernels import ref
    from repro.kernels.cim_matmul import adc_quant_pallas, cim_matmul_pallas

    rows = []
    m, k, n = 256, 1024, 256
    xi = jnp.round(jax.random.normal(jax.random.PRNGKey(0), (m, k)) * 30)
    wi = jnp.round(jax.random.normal(jax.random.PRNGKey(1), (k, n)) * 30)

    us_k, y_k = _time(
        lambda a, b: cim_matmul_pallas(a, b, rows=128, adc_bits=8, interpret=True),
        xi, wi,
    )
    us_r, y_r = _time(
        lambda a, b: ref.cim_matmul_ref(a, b, rows=128, adc_bits=8), xi, wi
    )
    err = float(jnp.abs(y_k - y_r).max())
    flops = 2 * m * k * n
    rows.append(
        (
            "kernel/cim_matmul_fakequant_256x1024x256",
            us_k,
            f"ref_us={us_r:.0f};maxerr={err:.1e};gflops_interp={flops / us_k / 1e3:.2f}",
        )
    )

    v = jax.random.uniform(jax.random.PRNGKey(2), (1024, 1024))
    us_q, _ = _time(lambda v: adc_quant_pallas(v, bits=5, interpret=True), v)
    us_qr, _ = _time(lambda v: ref.adc_quant_ref(v, 5), v)
    rows.append(("kernel/adc_quant_1Melem", us_q, f"ref_us={us_qr:.0f}"))

    from repro.kernels.flash_attention import flash_attention_pallas

    b, h, kv, s_, hd = 1, 4, 2, 512, 64
    q = jax.random.normal(jax.random.PRNGKey(3), (b, h, s_, hd))
    kk = jax.random.normal(jax.random.PRNGKey(4), (b, kv, s_, hd))
    vv = jax.random.normal(jax.random.PRNGKey(5), (b, kv, s_, hd))
    us_f, of = _time(lambda a, b_, c: flash_attention_pallas(a, b_, c, causal=True, interpret=True), q, kk, vv)
    us_fr, orf = _time(lambda a, b_, c: ref.flash_attention_ref(a, b_, c, causal=True), q, kk, vv)
    err = float(jnp.abs(of - orf).max())
    rows.append(("kernel/flash_attention_512", us_f, f"ref_us={us_fr:.0f};maxerr={err:.1e}"))
    return rows


def bench_train_step():
    """Reduced-config LM train step per arch family (CPU wall time)."""
    from repro.configs import ARCHS, reduced
    from repro.models import build_model

    rows = []
    for name in ("smollm-135m", "qwen3-moe-30b-a3b", "mamba2-130m", "zamba2-7b"):
        cfg = reduced(ARCHS[name])
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        b, s = 4, 128
        if cfg.input_kind == "embeddings":
            inputs = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model))
        else:
            inputs = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
        batch = {
            "inputs": inputs,
            "labels": jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, cfg.vocab),
        }
        step = jax.jit(jax.value_and_grad(lambda p: model.loss_fn(p, batch)[0]))
        us, (loss, _) = _time(lambda p: step(p), params)
        tok_s = b * s / (us / 1e6)
        rows.append(
            (f"train_step/{name}-reduced", us, f"loss={float(loss):.3f};tok_s={tok_s:.0f}")
        )
    return rows


def bench_serve():
    """Batched decode throughput, exact vs CiM-quantized inference."""
    import dataclasses

    from repro.configs import ARCHS, reduced
    from repro.core.cim_linear import CiMConfig
    from repro.launch.serve import ServeSettings, serve_batch

    rows = []
    base = reduced(ARCHS["smollm-135m"], n_layers=2)
    for tag, cfg in (
        ("exact", base),
        (
            "cim_fakequant",
            dataclasses.replace(
                base, cim=CiMConfig(mode="fake_quant", adc_bits=8, rows=64, ste=False)
            ),
        ),
    ):
        out = serve_batch(cfg, ServeSettings(batch=4, prompt_len=32, gen_len=16))
        rows.append(
            (
                f"serve/{tag}",
                out["decode_s"] / 15 * 1e6,
                f"decode_tok_s={out['decode_tok_s']:.1f};prefill_ms={out['prefill_s'] * 1e3:.0f}",
            )
        )
    return rows


def bench_dryrun_summary():
    """Roofline table from cached dry-run results (one row per cell)."""
    import json
    from pathlib import Path

    rows = []
    d = Path("results/dryrun_v3_opt")
    if not d.exists():
        d = Path("results/dryrun")
    if not d.exists():
        return [("dryrun/missing", 0.0, "run python -m repro.launch.dryrun --all")]
    for f in sorted(d.glob("*__singlepod.json")):
        r = json.loads(f.read_text())
        if r.get("status") != "ok":
            rows.append((f"dryrun/{r['arch']}/{r['shape']}", 0.0, f"FAILED:{r.get('error','')[:40]}"))
            continue
        rf = r["roofline"]
        rows.append(
            (
                f"dryrun/{r['arch']}/{r['shape']}",
                rf["t_compute"] * 1e6,
                f"bottleneck={rf['bottleneck']};t_c_ms={rf['t_compute']*1e3:.2f};"
                f"t_m_ms={rf['t_memory']*1e3:.2f};t_x_ms={rf['t_collective']*1e3:.2f};"
                f"mem_gib={r['memory']['bytes']/2**30:.2f};useful={rf['useful_ratio']:.2f}",
            )
        )
    return rows
