"""Fabric design-space sweep: networking mode x precision x chip budget.

Emits one JSON record per design point — chip area, digitization area,
conversions/cycle, throughput/mm^2, energy/conversion, and the iso-area
ratios against the conventional-ADC baseline — so successive PRs can track
the chip-level trajectory. ``shard_sweep_points`` extends the sweep across
1- / 4- / 16-chip meshes (``repro.fabric.shard``), reporting per-layer
on-chip EMA vs cross-chip reduce-scatter traffic; ``shard_backend_smoke``
executes the sharded matmul numerically through both chip backends
(sequential host loop vs real multi-device ``shard_map``) and compares;
``program_smoke`` runs the whole-model fused forward
(``repro.fabric.program``) against the per-layer loop and records the
measured-vs-modeled link-latency ratio; ``graph_smoke`` runs the
full-transformer-block fused GRAPH forward (``repro.fabric.graph``) with
real ``init_transformer`` weights against the per-node reference and checks
the collective census against the documented budget; ``scan_smoke``
compiles the SAME graph unrolled and scanned (``scan_layers=True``) at
``n_layers=8`` and records the compile-time speedup plus scanned-vs-unrolled
bit-exactness; ``autotune_smoke`` serves a mixed-length ragged request
trace through the bucketed fused-program cache (``repro.fabric.autotune``)
— bit-exact after pad-slicing, measured speedup vs the per-node loop, and
the autotuner's plan cost vs the default mesh; ``obs_smoke`` runs the
fused chain under an active ``repro.obs`` registry + JSONL tracer and
reports the canonical metric names, fallback-counter semantics, and
obs-on/off bit-identity the CI observability gate checks. Doubles as the
``fabric`` / ``fabric-autotune`` / ``fabric-smokes`` entries of
``benchmarks/run.py`` (``fabric_bench`` / ``autotune_bench`` /
``smoke_bench``, the latter two at 1x1 so they run without forced
devices) and the <30 s smoke benchmark of ``tools/ci_check.py``.

  PYTHONPATH=src python -m benchmarks.fabric_sweep [--out BENCH_fabric.json]
  PYTHONPATH=src:. XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      python -m benchmarks.fabric_sweep --backend-smoke
  PYTHONPATH=src:. XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      python -m benchmarks.fabric_sweep --program-smoke
  PYTHONPATH=src:. XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      python -m benchmarks.fabric_sweep --graph-smoke
  PYTHONPATH=src:. XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      python -m benchmarks.fabric_sweep --scan-smoke
  PYTHONPATH=src:. XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      python -m benchmarks.fabric_sweep --autotune-smoke
  PYTHONPATH=src:. XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      python -m benchmarks.fabric_sweep --obs-smoke
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path


def sweep_points(
    modes=("pair_sar", "hybrid", "flash"),
    bit_range=(4, 5, 6),
    array_budgets=(128, 256),  # >= one flash group even at 6 bits (3+63)
) -> list[dict]:
    from repro.core.energy_area import energy_pj
    from repro.fabric.pipeline import fabric_throughput, iso_area_comparison
    from repro.fabric.topology import FabricConfig

    from repro.obs import trace as obs_trace

    points = []
    for mode in modes:
        for bits in bit_range:
            flash_bits = min(2, bits - 1)
            for n_arrays in array_budgets:
                fb = FabricConfig(
                    mode=mode, adc_bits=bits, flash_bits=flash_bits, n_arrays=n_arrays
                )
                with obs_trace.span(
                    "fabric.sweep.point", mode=mode, adc_bits=bits,
                    n_arrays=fb.resolved_n_arrays(),
                ):
                    tp = fabric_throughput(fb)
                    iso = iso_area_comparison(fb)
                points.append(
                    {
                        "mode": mode,
                        "adc_bits": bits,
                        "n_arrays": fb.resolved_n_arrays(),
                        "chip_area_mm2": fb.chip_area_um2() / 1e6,
                        "chip_adc_area_mm2": fb.chip_adc_area_um2() / 1e6,
                        "conversions_per_cycle": tp["chip_conversions_per_cycle"],
                        "throughput_per_mm2": tp["throughput_per_mm2"],
                        "energy_pj_per_conversion": energy_pj(
                            fb.adc_style,
                            bits,
                            flash_bits=flash_bits,
                            flash_share=fb.n_cim_per_group,
                        ),
                        "adc_area_ratio": iso["adc_area_ratio"],
                        "iso_area_throughput_ratio": iso["throughput_ratio"],
                    }
                )
    return points


def shard_sweep_points(
    meshes=((1, 1), (2, 2), (4, 4)),  # 1-, 4-, 16-chip meshes (data x model)
    mode="hybrid",
    n_arrays=252,
    tokens=4,
) -> list[dict]:
    """Shard a smollm block across chip meshes; per-layer on-chip EMA vs
    cross-chip reduce-scatter traffic, per ``repro.fabric.shard``."""
    from repro.configs.registry import get_config
    from repro.fabric.report import sharded_fabric_report
    from repro.fabric.shard import shard_model
    from repro.fabric.topology import ChipMeshConfig, FabricConfig

    from repro.obs import trace as obs_trace

    cfg = get_config("smollm-135m")
    points = []
    for data, model in meshes:
        cm = ChipMeshConfig(
            data=data, model=model, fabric=FabricConfig(mode=mode, n_arrays=n_arrays)
        )
        t0 = time.perf_counter()
        with obs_trace.span("fabric.sweep.shard_point", mesh=f"{data}x{model}"):
            sps = shard_model(cfg, cm, tokens=tokens, block_only=True)
            rep = sharded_fabric_report(sps, cm)
        wall = time.perf_counter() - t0
        t = rep["totals"]
        points.append(
            {
                "mesh": f"{data}x{model}",
                "n_chips": cm.n_chips,
                "map_report_s": wall,
                "tiles_per_chip": t["tiles_per_chip"],
                "model_resident": t["model_resident"],
                "latency_s": t["latency_s"],
                "latency_s_overlapped": t["latency_s_overlapped"],
                "onchip_ema_bits_per_pass": t["ema_bits_per_pass"],
                "crosschip_bits_per_pass": t["crosschip_bits_per_pass"],
                "crosschip_energy_pj": t["crosschip_energy_pj"],
                "fallbacks": len(rep["mesh"]["fallbacks"]),
                "layers": [
                    {
                        "layer": r["layer"],
                        "k_splits": r["k_splits"],
                        "d_splits": r["d_splits"],
                        "onchip_ema_bits": r["ema_bits_per_pass"],
                        "crosschip_bits": r["crosschip_bits_per_pass"],
                    }
                    for r in rep["layers"]
                ],
            }
        )
    return points


def shard_backend_smoke(meshes=((1, 1), (2, 2))) -> dict:
    """Numeric backend smoke: execute the same sharded matmul through the
    sequential and shard_map backends and compare.

    Meant to run with forced host devices (``tools/ci_check.py`` launches it
    in a subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
    via ``python -m benchmarks.fabric_sweep --backend-smoke``); on a
    single-device host the shard_map points simply resolve to sequential and
    are reported as such.
    """
    import jax
    import numpy as np

    from repro.core.cim_linear import CiMConfig
    from repro.fabric import (
        ChipMeshConfig,
        FabricConfig,
        execute_matmul,
        execute_sharded_matmul,
        map_matmul,
        resolve_backend,
        shard_placement,
    )

    fb = FabricConfig(mode="pair_sar", rows=16, cols=32, n_arrays=8)
    noisy = CiMConfig(
        mode="bitplane", a_bits=4, w_bits=4, adc_bits=5, rows=16, ste=False,
        comparator_sigma=0.05,
    )
    key = jax.random.PRNGKey(0)
    nk = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (4, 64))
    w = jax.random.normal(jax.random.fold_in(key, 1), (64, 48))

    out = {"devices": len(jax.devices()), "points": []}
    for data, model in meshes:
        cm = ChipMeshConfig(data=data, model=model, fabric=fb)
        sp = shard_placement(map_matmul("matmul", 4, 64, 48, fb), cm)
        try:  # auto keeps 1x1 sequential; probe explicit shard_map eligibility
            resolve_backend(sp, "shard_map")
            shard_map_available = True
        except ValueError:
            shard_map_available = False
        t0 = time.perf_counter()
        y_seq = np.asarray(
            execute_sharded_matmul(x, w, cm, noisy, sharded=sp, key=nk,
                                   backend="sequential")
        )
        t_seq = time.perf_counter() - t0
        rec = {
            "mesh": f"{data}x{model}",
            "backend_auto": resolve_backend(sp, "auto"),
            "shard_map_available": shard_map_available,
            "sequential_s": t_seq,
            "crosschip_bits_per_pass": sp.crosschip_bits_per_pass,
        }
        if shard_map_available:
            t0 = time.perf_counter()
            y_sm = np.asarray(
                execute_sharded_matmul(x, w, cm, noisy, sharded=sp, key=nk,
                                       backend="shard_map")
            )
            rec["shard_map_s"] = time.perf_counter() - t0
            rec["max_abs_diff_vs_sequential"] = float(np.abs(y_sm - y_seq).max())
            if (data, model) == (1, 1):
                y_ref = np.asarray(execute_matmul(x, w, fb, noisy, key=nk))
                rec["bit_exact_1x1_vs_execute"] = bool((y_sm == y_ref).all())
        out["points"].append(rec)
    return out


def program_smoke(mesh=(2, 2)) -> dict:
    """Fused whole-model forward smoke (``repro.fabric.program``): compile a
    small 3-layer chain, check 1x1 bit-exactness (noisy ADC included) and
    multi-chip agreement vs the per-layer ``execute_sharded_matmul`` loop,
    count the fused program's collectives, and record the measured-vs-modeled
    link-latency ratio. Meant for forced host devices
    (``python -m benchmarks.fabric_sweep --program-smoke`` inside
    ``tools/ci_check.py``'s 8-device subprocess -> ``BENCH_fabric_program.json``).
    """
    import jax
    import numpy as np

    from repro.core.cim_linear import CiMConfig
    from repro.fabric import (
        ChipMeshConfig,
        FabricConfig,
        compile_forward,
        map_matmul,
        measure_forward,
        per_layer_forward,
        shard_placement,
    )

    fb = FabricConfig(mode="pair_sar", rows=16, cols=32, n_arrays=8)
    noisy = CiMConfig(
        mode="bitplane", a_bits=4, w_bits=4, adc_bits=5, rows=16, ste=False,
        comparator_sigma=0.05,
    )
    shapes = [("l0", 4, 64, 64), ("l1", 4, 64, 96), ("l2", 4, 96, 32)]

    def chain(cm):
        return [
            shard_placement(map_matmul(n, m, k, nn, fb, cim=noisy), cm)
            for n, m, k, nn in shapes
        ]

    nk = jax.random.PRNGKey(7)
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64))
    out = {"devices": len(jax.devices()), "mesh": f"{mesh[0]}x{mesh[1]}"}

    # 1x1: the fused program must be bit-for-bit the per-layer loop
    cm1 = ChipMeshConfig(fabric=fb)
    prog1 = compile_forward(chain(cm1), cm1, noisy)
    ws = prog1.random_weights(jax.random.PRNGKey(1))
    y1 = np.asarray(prog1(x, ws, key=nk))
    y1_ref = np.asarray(
        per_layer_forward(x, ws, prog1.placements, cm1, noisy, key=nk,
                          backend="sequential")
    )
    out["backend_1x1"] = prog1.backend
    out["bit_exact_1x1"] = bool((y1 == y1_ref).all())

    # multi-chip: float agreement + collective census + measured timings
    cmn = ChipMeshConfig(data=mesh[0], model=mesh[1], fabric=fb)
    prog = compile_forward(chain(cmn), cmn, noisy)
    out["backend"] = prog.backend
    out["problems"] = prog.problems
    y = np.asarray(prog(x, ws, key=nk))
    y_ref = np.asarray(
        per_layer_forward(x, ws, prog.placements, cmn, noisy, key=nk,
                          backend="sequential")
    )
    out["max_abs_diff_vs_per_layer"] = float(np.abs(y - y_ref).max())
    if prog.backend == "shard_map":
        out["collectives"] = prog.collective_counts(x, ws, key=nk)
    out["measure"] = measure_forward(
        prog, x=x, weights=ws, key=nk, iters=2,
        per_layer_backend="sequential", per_layer_iters=1,
    )
    out["measured_over_modeled"] = out["measure"]["measured_over_modeled"]
    out["link_clock_calibration"] = out["measure"]["link_clock_calibration"]
    # a second measure on warm jit caches: tools/ci_check.py gates that the
    # calibration constant is stable across runs, never its magnitude
    # (per_layer=False — the stability run only needs the fused twins)
    m2 = measure_forward(prog, x=x, weights=ws, key=nk, iters=2, per_layer=False)
    out["link_clock_calibration_runs"] = [
        out["measure"]["link_clock_calibration"],
        m2["link_clock_calibration"],
    ]
    return out


def graph_smoke(mesh=(2, 2)) -> dict:
    """Full-transformer-block fused GRAPH smoke (``repro.fabric.graph``):
    run REAL ``init_transformer`` weights through the fused graph forward —
    siblings, attention mixing, norms, residuals — checking 1x1
    bit-exactness vs the per-node reference (noisy ADC included),
    multi-chip agreement, and the collective census against the documented
    budget (per-sibling scatters enumerated, ONE trailing all-gather).
    Meant for forced host devices
    (``python -m benchmarks.fabric_sweep --graph-smoke`` inside
    ``tools/ci_check.py``'s 8-device subprocess -> ``BENCH_fabric_graph.json``).
    """
    import jax
    import numpy as np

    from repro.configs.base import ModelConfig
    from repro.core.cim_linear import CiMConfig
    from repro.fabric import (
        ChipMeshConfig,
        FabricConfig,
        compile_graph_forward,
        measure_forward,
        transformer_graph_weights,
    )
    from repro.models.transformer import init_transformer

    # graph-eligible on a 2x2 mesh: every K tile-aligns (64/128 % 32 == 0)
    # and q/kv heads (4/2) divide the model axis. ONE block keeps the smoke
    # inside the CI budget; the >=2-block acceptance lives in tier-1
    # (tests/test_fabric_graph.py)
    cfg = ModelConfig(
        name="graph-smoke", family="dense", n_layers=1, d_model=64, vocab=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, pad_vocab_multiple=16,
        param_dtype="float32", compute_dtype="float32",
    )
    fb = FabricConfig(mode="pair_sar", rows=16, cols=32, n_arrays=8)
    noisy = CiMConfig(
        mode="bitplane", a_bits=4, w_bits=4, adc_bits=5, rows=16, ste=False,
        comparator_sigma=0.05,
    )
    params = init_transformer(jax.random.PRNGKey(0), cfg)
    ws = transformer_graph_weights(params, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, cfg.d_model))
    nk = jax.random.PRNGKey(7)
    out = {"devices": len(jax.devices()), "mesh": f"{mesh[0]}x{mesh[1]}"}

    # 1x1: the fused graph must be bit-for-bit the per-node reference
    cm1 = ChipMeshConfig(fabric=fb)
    prog1 = compile_graph_forward(cfg, cm1, noisy, tokens=8)
    out["n_nodes"] = len(prog1.graph.nodes)
    out["n_matmuls"] = len(prog1.placements)
    out["backend_1x1"] = prog1.backend
    y1 = np.asarray(prog1(x, ws, key=nk))
    y1_ref = np.asarray(prog1.reference_forward(x, ws, key=nk))
    out["bit_exact_1x1"] = bool((y1 == y1_ref).all())

    # multi-chip: float agreement + census-vs-budget + measured timings
    cmn = ChipMeshConfig(data=mesh[0], model=mesh[1], fabric=fb)
    prog = compile_graph_forward(cfg, cmn, noisy, tokens=8)
    out["backend"] = prog.backend
    out["problems"] = prog.problems
    y = np.asarray(prog(x, ws, key=nk))
    y_ref = np.asarray(prog.reference_forward(x, ws, key=nk))
    out["max_abs_diff_vs_reference"] = float(np.abs(y - y_ref).max())
    if prog.backend == "shard_map":
        out["collectives"] = prog.collective_counts(key=nk)
        out["collective_budget"] = prog.collective_budget()
        out["budget_match"] = out["collectives"] == out["collective_budget"]
    out["measure"] = measure_forward(
        prog, x=x, weights=ws, key=nk, iters=1,
        per_layer_backend="sequential", per_layer_iters=1,
    )
    out["measured_over_modeled"] = out["measure"]["measured_over_modeled"]
    out["link_clock_calibration"] = out["measure"]["link_clock_calibration"]
    # second warm measure for the CI stability-across-runs gate (fused
    # twins only — the per-node reference is the expensive part)
    m2 = measure_forward(prog, x=x, weights=ws, key=nk, iters=1, per_layer=False)
    out["link_clock_calibration_runs"] = [
        out["measure"]["link_clock_calibration"],
        m2["link_clock_calibration"],
    ]
    return out


def scan_smoke(depth: int = 8, mesh=(2, 2)) -> dict:
    """Scan-over-layers smoke (``compile_graph_forward(scan_layers=True)``):
    at ``depth`` transformer blocks, AOT trace+compile the unrolled and the
    scanned 1x1 programs (``fn.lower(...).compile()`` isolates exactly the
    cost the scan collapses), run BOTH compiled executables on the same
    noisy-ADC inputs and check bit-exactness, then check the scanned
    program's collective census on the forced mesh against the documented
    budget AND the per-block census × ``n_blocks`` + tail decomposition.
    Meant for forced host devices
    (``python -m benchmarks.fabric_sweep --scan-smoke`` inside
    ``tools/ci_check.py``'s 8-device subprocess -> ``BENCH_fabric_scan.json``).
    """
    import jax
    import numpy as np

    from repro.configs.base import ModelConfig
    from repro.core.cim_linear import CiMConfig
    from repro.fabric import ChipMeshConfig, FabricConfig, compile_graph_forward

    cfg = ModelConfig(
        name="scan-smoke", family="dense", n_layers=depth, d_model=64, vocab=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, pad_vocab_multiple=16,
        param_dtype="float32", compute_dtype="float32",
    )
    fb = FabricConfig(mode="pair_sar", rows=16, cols=32, n_arrays=8)
    noisy = CiMConfig(
        mode="bitplane", a_bits=4, w_bits=4, adc_bits=5, rows=16, ste=False,
        comparator_sigma=0.05,
    )
    out = {
        "devices": len(jax.devices()), "n_layers": depth,
        "mesh": f"{mesh[0]}x{mesh[1]}",
    }
    cm1 = ChipMeshConfig(fabric=fb)
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, cfg.d_model))
    compiled = {}
    for tag, scan in (("unrolled", False), ("scanned", True)):
        prog = compile_graph_forward(cfg, cm1, noisy, tokens=4, scan_layers=scan)
        # random_weights stacks the SAME per-layer draws for the scanned
        # form, so one key yields corresponding weights in both programs
        args = prog._fused_args(x, prog.random_weights(jax.random.PRNGKey(3)), key)
        t0 = time.perf_counter()
        exe = prog._fused(True).lower(*args).compile()
        out[f"{tag}_compile_s"] = time.perf_counter() - t0
        compiled[tag] = (exe, args)
    out["compile_speedup"] = out["unrolled_compile_s"] / out["scanned_compile_s"]
    y_un = np.asarray(compiled["unrolled"][0](*compiled["unrolled"][1])[0])
    y_sc = np.asarray(compiled["scanned"][0](*compiled["scanned"][1])[0])
    out["bit_exact_1x1"] = bool((y_un == y_sc).all())
    out["max_abs_diff_1x1"] = float(np.abs(y_un - y_sc).max())

    # census on the forced mesh is trace-only (jax.make_jaxpr, no XLA
    # compile) — cheap at any depth, which is itself part of the point
    cmn = ChipMeshConfig(data=mesh[0], model=mesh[1], fabric=fb)
    sc = compile_graph_forward(cfg, cmn, noisy, tokens=8, scan_layers=True)
    out["backend"] = sc.backend
    out["problems"] = sc.problems
    if sc.backend == "shard_map":
        counts = sc.collective_counts(key=key)
        budget = sc.collective_budget()
        blk = sc.block_graph.block_census(cmn.model)
        tail = sc.tail_graph.collective_budget(cmn.model)
        out["collectives"] = counts
        out["collective_budget"] = budget
        out["block_census_x_layers"] = {
            k: blk[k] * sc.n_blocks + tail[k] for k in blk
        }
        out["budget_match"] = (
            counts == budget == out["block_census_x_layers"]
        )
    return out


def obs_smoke(mesh=(2, 2)) -> dict:
    """Observability smoke (``repro.obs``): run the fused 3-layer chain under
    an active metrics registry + JSONL tracer and report everything the CI
    gate needs — the required metric names, the fallback counter staying 0 on
    an aligned batch and reaching exactly 1 (reason ``ragged_batch``) on a
    ragged batch, a parse-clean JSONL trace log, and bit-identical fused
    outputs with observability on vs off. Meant for forced host devices
    (``python -m benchmarks.fabric_sweep --obs-smoke`` inside
    ``tools/ci_check.py``'s 8-device subprocess -> ``BENCH_obs.json``).
    """
    import os
    import tempfile

    import jax
    import numpy as np

    from repro import obs
    from repro.core.cim_linear import CiMConfig
    from repro.fabric import (
        ChipMeshConfig,
        FabricConfig,
        compile_forward,
        map_matmul,
        shard_placement,
    )

    fb = FabricConfig(mode="pair_sar", rows=16, cols=32, n_arrays=8)
    noisy = CiMConfig(
        mode="bitplane", a_bits=4, w_bits=4, adc_bits=5, rows=16, ste=False,
        comparator_sigma=0.05,
    )
    shapes = [("l0", 4, 64, 64), ("l1", 4, 64, 96), ("l2", 4, 96, 32)]
    cmn = ChipMeshConfig(data=mesh[0], model=mesh[1], fabric=fb)
    chain = [
        shard_placement(map_matmul(n, m, k, nn, fb, cim=noisy), cmn)
        for n, m, k, nn in shapes
    ]
    prog = compile_forward(chain, cmn, noisy)
    ws = prog.random_weights(jax.random.PRNGKey(1))
    nk = jax.random.PRNGKey(7)
    x_aligned = jax.random.normal(jax.random.PRNGKey(0), (4, 64))
    x_ragged = x_aligned[:3]  # 3 rows % data axis 2 != 0 -> documented fallback

    out = {
        "devices": len(jax.devices()),
        "mesh": f"{mesh[0]}x{mesh[1]}",
        "backend": prog.backend,
    }

    # baseline with observability OFF — the neutrality reference
    y_off = np.asarray(prog(x_aligned, ws, key=nk))

    fd, jsonl_path = tempfile.mkstemp(suffix=".jsonl")
    os.close(fd)
    os.unlink(jsonl_path)  # JsonlSink lazily (re)creates it
    try:
        with obs.tracing(jsonl=jsonl_path), obs.collecting() as reg:
            y_on = np.asarray(prog(x_aligned, ws, key=nk))
            out["fallbacks_aligned"] = obs.get_value(
                "fabric_fallback_total", reason=obs.REASON_RAGGED_BATCH
            )
            _ = np.asarray(prog(x_ragged, ws, key=nk))
            out["fallbacks_ragged"] = obs.get_value(
                "fabric_fallback_total", reason=obs.REASON_RAGGED_BATCH
            )
            out["fused_requests"] = obs.get_value(
                "fabric_requests_total", path="fused"
            )
            out["fallback_requests"] = obs.get_value(
                "fabric_requests_total", path="fallback"
            )
            out["conversions_total"] = obs.get_value("fabric_conversions_total")
            out["link_bits_total"] = obs.get_value("fabric_link_bits_total")
            out["metric_names"] = reg.names()
            out["prometheus_lines"] = len(reg.prometheus_text().splitlines())
        out["bit_identical_with_obs"] = bool((y_on == y_off).all())
        records = obs.read_jsonl(jsonl_path)  # raises on any unparseable line
        out["jsonl_records"] = len(records)
        out["jsonl_names"] = sorted({r["name"] for r in records})
    finally:
        if os.path.exists(jsonl_path):
            os.unlink(jsonl_path)
    return out


def autotune_smoke(mesh=(2, 2)) -> dict:
    """Continuous-batching smoke (``repro.fabric.autotune``): serve a
    mixed-length ragged request trace through the bucketed fused-program
    cache and check (a) the padded fused result is bit-exact to the
    unpadded per-node reference after slicing (noiseless AND noisy ADC —
    per-row noise keys make pad rows draw-invisible), (b) the measured
    trace wall-clock beats the per-node fallback loop, (c) the autotuner's
    cost-model plan is never costlier than the default mesh with one
    max-batch bucket. Meant for forced host devices
    (``python -m benchmarks.fabric_sweep --autotune-smoke`` inside
    ``tools/ci_check.py``'s 8-device subprocess ->
    ``BENCH_fabric_autotune.json``).
    """
    import dataclasses

    import jax
    import numpy as np

    from repro.configs.base import ModelConfig
    from repro.core.cim_linear import CiMConfig
    from repro.fabric import (
        BucketedGraphCache,
        ChipMeshConfig,
        FabricConfig,
        autotune_plan,
        autotune_section,
        request_histogram,
        transformer_graph_weights,
    )
    from repro.models.transformer import init_transformer

    # the graph-smoke config: 2x2-eligible (K tile-aligned, GQA heads 4/2)
    cfg = ModelConfig(
        name="autotune-smoke", family="dense", n_layers=1, d_model=64,
        vocab=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
        pad_vocab_multiple=16, param_dtype="float32", compute_dtype="float32",
    )
    fb = FabricConfig(mode="pair_sar", rows=16, cols=32, n_arrays=8)
    cim = CiMConfig(
        mode="bitplane", a_bits=4, w_bits=4, adc_bits=5, rows=16, ste=False
    )
    noisy = dataclasses.replace(cim, comparator_sigma=0.05)
    params = init_transformer(jax.random.PRNGKey(0), cfg)
    ws = transformer_graph_weights(params, cfg)
    cm = ChipMeshConfig(data=mesh[0], model=mesh[1], fabric=fb)
    seq = 4
    out = {"devices": len(jax.devices()), "mesh": f"{mesh[0]}x{mesh[1]}"}

    # ragged batch on the bucketed fused path: B=3 pads to the 4-bucket
    cache = BucketedGraphCache(cfg, cm, cim, buckets=(4,), seq=seq)
    xs = {
        b: jax.random.normal(jax.random.PRNGKey(b), (b, seq, cfg.d_model))
        for b in (1, 2, 3)
    }
    prog = cache.program_for(4)
    out["backend"] = prog.backend
    y = np.asarray(cache(xs[3], ws))
    y_ref = np.asarray(prog.reference_forward(xs[3], ws))
    out["bit_exact_ragged"] = bool((y == y_ref).all())

    # noisy ADC: pad rows must not consume noise-key draws
    nk = jax.random.PRNGKey(7)
    cache_n = BucketedGraphCache(cfg, cm, noisy, buckets=(4,), seq=seq)
    yn = np.asarray(cache_n(xs[3], ws, key=nk))
    yn_ref = np.asarray(
        cache_n.program_for(4, noisy=True).reference_forward(xs[3], ws, key=nk)
    )
    out["bit_exact_ragged_noisy"] = bool((yn == yn_ref).all())

    # mixed-length trace: bucketed fused serving vs the per-node fallback
    # loop every ragged batch used to take (warm both paths first)
    trace = [3, 1, 2, 3]
    for b in set(trace):
        jax.block_until_ready(cache(xs[b], ws))
        jax.block_until_ready(prog.reference_forward(xs[b], ws))
    t0 = time.perf_counter()
    for b in trace:
        jax.block_until_ready(cache(xs[b], ws))
    out["fused_trace_s"] = time.perf_counter() - t0
    t0 = time.perf_counter()
    for b in trace:
        jax.block_until_ready(prog.reference_forward(xs[b], ws))
    out["pernode_trace_s"] = time.perf_counter() - t0
    out["ragged_mix_speedup"] = out["pernode_trace_s"] / max(
        out["fused_trace_s"], 1e-9
    )
    out["cache"] = cache.stats()

    # the autotuner's plan must never cost more than the default mesh with
    # a single max-batch bucket (the baseline is in its search space)
    plan = autotune_plan(
        cfg, request_histogram(trace), cm.n_chips, fb, seq=seq, cim=cim,
        default_mesh=mesh,
    )
    out["plan"] = autotune_section(plan)
    out["plan_cost_le_default"] = (
        plan.expected_latency_s <= plan.baseline_latency_s
    )
    return out


def fabric_mapping_smoke() -> dict:
    """Map a smollm block on a hybrid fabric — the perf-trajectory anchor."""
    from repro.configs.registry import get_config
    from repro.fabric.mapper import map_model
    from repro.fabric.report import fabric_report
    from repro.fabric.topology import FabricConfig

    fb = FabricConfig(mode="hybrid", n_arrays=252)
    t0 = time.perf_counter()
    placements = map_model(get_config("smollm-135m"), fb, tokens=4, block_only=True)
    report = fabric_report(placements, fb)
    wall = time.perf_counter() - t0
    return {
        "map_report_s": wall,
        "tiles": report["totals"]["tiles"],
        "conversions": report["totals"]["conversions"],
        "latency_s": report["totals"]["latency_s"],
        "adc_area_ratio_vs_sar": report["paper_ratios"]["adc_area_ratio_vs_sar"],
        "adc_area_ratio_vs_flash": report["paper_ratios"]["adc_area_ratio_vs_flash"],
        "iso_area_throughput_ratio": report["iso_area"]["throughput_ratio"],
    }


def fabric_bench() -> list[tuple]:
    """benchmarks/run.py rows: name, us_per_call, derived."""
    rows = []
    t0 = time.perf_counter()
    points = sweep_points()
    us = (time.perf_counter() - t0) / max(len(points), 1) * 1e6
    for p in points:
        rows.append(
            (
                f"fabric/{p['mode']}_b{p['adc_bits']}_a{p['n_arrays']}",
                us,
                f"conv_per_cyc={p['conversions_per_cycle']:.2f};"
                f"per_mm2={p['throughput_per_mm2']:.1f};"
                f"iso_ratio={p['iso_area_throughput_ratio']:.2f}",
            )
        )
    smoke = fabric_mapping_smoke()
    rows.append(
        (
            "fabric/map_smollm_block_hybrid252",
            smoke["map_report_s"] * 1e6,
            f"tiles={smoke['tiles']};iso_ratio={smoke['iso_area_throughput_ratio']:.2f}",
        )
    )
    for p in shard_sweep_points():
        rows.append(
            (
                f"fabric/shard_smollm_block_{p['mesh']}",
                p["map_report_s"] * 1e6,
                f"chips={p['n_chips']};onchip_ema={p['onchip_ema_bits_per_pass']:.3g};"
                f"xchip={p['crosschip_bits_per_pass']:.3g};"
                f"resident={int(p['model_resident'])}",
            )
        )
    return rows


def autotune_bench() -> list[tuple]:
    """benchmarks/run.py rows for the continuous-batching autotune smoke.

    Runs at 1x1 so it works without forced host devices; the 8-device
    gated version lives in ``tools/ci_check.py`` (``run_autotune_smoke``
    -> ``BENCH_fabric_autotune.json``).
    """
    s = autotune_smoke(mesh=(1, 1))
    return [
        (
            "fabric-autotune/ragged_trace_1x1",
            s["fused_trace_s"] * 1e6,
            f"speedup={s['ragged_mix_speedup']:.1f};"
            f"bit_exact={int(s['bit_exact_ragged'] and s['bit_exact_ragged_noisy'])};"
            f"plan={s['plan']['mesh']}/{'-'.join(map(str, s['plan']['buckets']))};"
            f"hits={s['cache']['hits']}",
        )
    ]


def _smoke_row(name: str, out: dict, wall_s: float) -> tuple:
    """Summarise a smoke dict as a CSV row: first few scalar metrics."""
    keys = [
        k for k, v in out.items()
        if isinstance(v, (int, float)) and not isinstance(v, bool)
    ][:3]
    derived = ";".join(f"{k}={out[k]:.4g}" for k in keys) or "ok"
    return (f"fabric-smokes/{name}", wall_s * 1e6, derived)


def smoke_bench() -> list[tuple]:
    """benchmarks/run.py rows mirroring every other ``BENCH_*.json`` device
    smoke of ``tools/ci_check.py``, run at 1x1 so they work without forced
    host devices. Keeps each CI trajectory file discoverable from the bench
    harness (``benchmarks/run.py`` asserts the mapping is total)."""
    rows = []
    for name, thunk in (
        ("shard", lambda: shard_backend_smoke(meshes=((1, 1),))),
        ("program", lambda: program_smoke(mesh=(1, 1))),
        ("graph", lambda: graph_smoke(mesh=(1, 1))),
        ("scan", lambda: scan_smoke(mesh=(1, 1))),
        ("obs", lambda: obs_smoke(mesh=(1, 1))),
    ):
        t0 = time.perf_counter()
        out = thunk()
        rows.append(_smoke_row(name, out, time.perf_counter() - t0))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_fabric.json")
    ap.add_argument(
        "--backend-smoke",
        action="store_true",
        help="print the shard_backend_smoke() JSON to stdout and exit "
        "(tools/ci_check.py runs this in a forced-8-device subprocess)",
    )
    ap.add_argument(
        "--program-smoke",
        action="store_true",
        help="print the program_smoke() JSON (fused whole-model forward vs "
        "per-layer loop + measured/modeled link latency) to stdout and exit "
        "(tools/ci_check.py runs this in a forced-8-device subprocess)",
    )
    ap.add_argument(
        "--graph-smoke",
        action="store_true",
        help="print the graph_smoke() JSON (full-transformer-block fused "
        "graph with real init_transformer weights vs the per-node reference "
        "+ collective census vs budget) to stdout and exit "
        "(tools/ci_check.py runs this in a forced-8-device subprocess)",
    )
    ap.add_argument(
        "--scan-smoke",
        action="store_true",
        help="print the scan_smoke() JSON (scan-over-layers vs unrolled "
        "graph compile wall-clock at n_layers=8, bit-exact noisy forward, "
        "census == per-block x n_layers + tail) to stdout and exit "
        "(tools/ci_check.py runs this in a forced-8-device subprocess)",
    )
    ap.add_argument(
        "--autotune-smoke",
        action="store_true",
        help="print the autotune_smoke() JSON (ragged mixed-length trace "
        "through the bucketed fused-program cache: bit-exact after "
        "pad-slicing, measured speedup vs the per-node loop, autotuner "
        "plan cost vs the default mesh) to stdout and exit "
        "(tools/ci_check.py runs this in a forced-8-device subprocess)",
    )
    ap.add_argument(
        "--obs-smoke",
        action="store_true",
        help="print the obs_smoke() JSON (repro.obs metric names, fallback "
        "counter semantics, JSONL parse check, obs-on/off bit-identity) to "
        "stdout and exit "
        "(tools/ci_check.py runs this in a forced-8-device subprocess)",
    )
    args = ap.parse_args()
    if args.backend_smoke:
        print(json.dumps(shard_backend_smoke(), indent=2, default=float))
        return
    if args.program_smoke:
        print(json.dumps(program_smoke(), indent=2, default=float))
        return
    if args.graph_smoke:
        print(json.dumps(graph_smoke(), indent=2, default=float))
        return
    if args.scan_smoke:
        print(json.dumps(scan_smoke(), indent=2, default=float))
        return
    if args.autotune_smoke:
        print(json.dumps(autotune_smoke(), indent=2, default=float))
        return
    if args.obs_smoke:
        print(json.dumps(obs_smoke(), indent=2, default=float))
        return
    t0 = time.perf_counter()
    # shard-sweep data is written by tools/ci_check.py to BENCH_fabric_shard.json
    # (single source of truth); here it only feeds the run.py bench rows
    payload = {"sweep": sweep_points(), "smoke": fabric_mapping_smoke()}
    payload["wall_s"] = time.perf_counter() - t0
    Path(args.out).write_text(json.dumps(payload, indent=2, default=float))
    print(f"[fabric_sweep] {len(payload['sweep'])} design points -> {args.out} "
          f"({payload['wall_s']:.1f}s)")


if __name__ == "__main__":
    main()
