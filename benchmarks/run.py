"""Benchmark harness: one function per paper table/figure + framework benches.

Prints ``name,us_per_call,derived`` CSV. Usage:
  PYTHONPATH=src python -m benchmarks.run [--only fig4,table1] [--skip-slow]

Every ``BENCH_*.json`` trajectory file written by ``tools/ci_check.py`` must
map to a bench entry here (``BENCH_TRAJECTORIES``) — ``main`` asserts the
mapping is total so new CI smokes stay discoverable from the bench harness.
"""

from __future__ import annotations

import argparse
import re
import sys
from pathlib import Path

# BENCH_*.json writer in tools/ci_check.py -> the bench entry that exercises
# the same code path from this harness (single-device where CI forces 8).
BENCH_TRAJECTORIES = {
    "BENCH_fabric.json": "fabric",
    "BENCH_fabric_shard.json": "fabric-smokes",
    "BENCH_fabric_program.json": "fabric-smokes",
    "BENCH_fabric_graph.json": "fabric-smokes",
    "BENCH_fabric_scan.json": "fabric-smokes",
    "BENCH_obs.json": "fabric-smokes",
    "BENCH_fabric_autotune.json": "fabric-autotune",
}

# benches slow enough to skip under --skip-slow (MNIST training + the
# compile-heavy CI smoke mirrors)
SLOW_BENCHES = ("fig7cd", "fabric-smokes")


def check_bench_coverage(bench_names) -> None:
    """Every BENCH_*.json mentioned in tools/ci_check.py must map (via
    BENCH_TRAJECTORIES) to an existing bench entry."""
    src = (Path(__file__).resolve().parents[1] / "tools" / "ci_check.py").read_text()
    writers = sorted(set(re.findall(r"BENCH_[A-Za-z0-9_]+\.json", src)))
    missing = [
        w for w in writers if BENCH_TRAJECTORIES.get(w) not in bench_names
    ]
    if missing:
        raise SystemExit(
            "BENCH writers in tools/ci_check.py without a matching "
            f"benchmarks entry: {missing} (update BENCH_TRAJECTORIES and the "
            "benches list in benchmarks/run.py)"
        )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated bench name filter")
    ap.add_argument(
        "--skip-slow", action="store_true",
        help=f"skip the slow benches: {', '.join(SLOW_BENCHES)}",
    )
    args = ap.parse_args()

    from benchmarks import fabric_sweep, framework, paper_figs

    benches = [
        ("table1", paper_figs.table1_adc_area_energy),
        ("fig4", paper_figs.fig4_asymmetric_search),
        ("fig6", paper_figs.fig6_nonlinearity),
        ("fig7ab", paper_figs.fig7_design_space),
        ("fig3", paper_figs.fig3_hybrid_schedule),
        ("fig7cd", paper_figs.fig7_mnist),
        ("fabric", fabric_sweep.fabric_bench),
        ("fabric-autotune", fabric_sweep.autotune_bench),
        ("fabric-smokes", fabric_sweep.smoke_bench),
        ("kernels", framework.bench_cim_kernels),
        ("train", framework.bench_train_step),
        ("serve", framework.bench_serve),
        ("dryrun", framework.bench_dryrun_summary),
    ]
    check_bench_coverage({name for name, _ in benches})
    if args.skip_slow:
        benches = [(n, f) for n, f in benches if n not in SLOW_BENCHES]

    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in benches:
        if only and name not in only:
            continue
        try:
            for row in fn():
                print(f"{row[0]},{row[1]:.1f},{row[2]}")
                sys.stdout.flush()
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name}/ERROR,0.0,{type(e).__name__}:{e}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
