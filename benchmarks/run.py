"""Benchmark harness: one function per paper table/figure + framework benches.

Prints ``name,us_per_call,derived`` CSV. Usage:
  PYTHONPATH=src python -m benchmarks.run [--only fig4,table1] [--skip-slow]
"""

from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated bench name filter")
    ap.add_argument("--skip-slow", action="store_true", help="skip MNIST training bench")
    args = ap.parse_args()

    from benchmarks import fabric_sweep, framework, paper_figs

    benches = [
        ("table1", paper_figs.table1_adc_area_energy),
        ("fig4", paper_figs.fig4_asymmetric_search),
        ("fig6", paper_figs.fig6_nonlinearity),
        ("fig7ab", paper_figs.fig7_design_space),
        ("fig3", paper_figs.fig3_hybrid_schedule),
        ("fabric", fabric_sweep.fabric_bench),
        ("kernels", framework.bench_cim_kernels),
        ("train", framework.bench_train_step),
        ("serve", framework.bench_serve),
        ("dryrun", framework.bench_dryrun_summary),
    ]
    if not args.skip_slow:
        benches.insert(5, ("fig7cd", paper_figs.fig7_mnist))

    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in benches:
        if only and name not in only:
            continue
        try:
            for row in fn():
                print(f"{row[0]},{row[1]:.1f},{row[2]}")
                sys.stdout.flush()
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name}/ERROR,0.0,{type(e).__name__}:{e}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
