"""Map a smollm-135m attention+MLP block onto a hybrid CiM fabric.

Demonstrates the chip-level story of the paper end to end:

  1. place the block's seven linears onto a hybrid (Fig. 3) fabric of
     collaborating 16x32 arrays;
  2. print the area / energy / latency / EMA rollup, including the paper's
     chip-level ADC area ratios (~25x vs dedicated SAR, ~51x vs Flash) and
     the iso-area throughput comparison against a conventional-ADC fabric;
  3. numerically execute the mapped q_proj / gate_proj placements and verify
     they match the unmapped ``cim_linear`` op bit-for-bit (bitplane mode)
     and to float tolerance (fake_quant via the fused Pallas kernel);
  4. shard the mapped block across a 2x2 chip mesh (``repro.fabric.shard``):
     verify the 1x1-mesh sharded run is bit-exact vs the unsharded executor,
     and print the mesh rollup separating on-chip EMA from cross-chip
     reduce-scatter traffic;
  5. compile the block's forward CHAIN (q -> o -> gate -> down) into ONE
     fused shard_map program (``repro.fabric.compile_forward``): layer i's
     reduce-scatter output stays sharded as layer i+1's input, one
     all-gather total, bit-exact vs the per-layer loop — and report the
     measured-vs-modeled link latency (``measure_forward``).

``--graph`` instead demos the FULL-transformer-block graph forward
(``repro.fabric.compile_graph_forward``): real ``init_transformer`` weights
adapted via ``repro.fabric.transformer_graph_weights`` run through the fused
graph — siblings, attention mixing, norms, residuals included — printing the
fused-vs-reference max abs diff, the collective census vs the documented
budget, and the sibling-inclusive markdown report; then the scan-over-layers
form (``scan_layers=True`` + ``stack_block_weights``) is checked bit-exact
against the unrolled program and both trace+compile times are printed.

  PYTHONPATH=src python examples/fabric_map.py [--graph]
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.core.cim_linear import CiMConfig, cim_linear
from repro.fabric import (
    ChipMeshConfig,
    FabricConfig,
    execute_linear,
    execute_sharded_matmul,
    fabric_report,
    map_model,
    render_markdown,
    shard_model,
    sharded_fabric_report,
)


def main():
    cfg = get_config("smollm-135m")
    fabric = FabricConfig(mode="hybrid", rows=16, cols=32, adc_bits=5, n_arrays=252)
    placements = map_model(cfg, fabric, tokens=4, block_only=True)
    report = fabric_report(placements, fabric)
    print(render_markdown(report))

    ratios = report["paper_ratios"]
    iso = report["iso_area"]
    assert ratios["adc_area_ratio_vs_sar"] > 24, ratios
    assert ratios["adc_area_ratio_vs_flash"] > 50, ratios
    assert iso["throughput_ratio"] >= 1.0, iso

    # --- mapped vs unmapped numerics on real block shapes -------------------
    d, ff = cfg.d_model, cfg.d_ff
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (4, d))
    w_q = jax.random.normal(jax.random.fold_in(key, 1), (d, cfg.n_heads * cfg.head_dim))
    w_gate = jax.random.normal(jax.random.fold_in(key, 2), (d, ff))

    cim_bp = CiMConfig(mode="bitplane", a_bits=4, w_bits=4, adc_bits=5, rows=16, ste=False)
    for name, w in (("q_proj", w_q), ("gate_proj", w_gate)):
        y_map = np.asarray(execute_linear(x, w, fabric=fabric, cim=cim_bp))
        y_ref = np.asarray(cim_linear(x, w, cfg=cim_bp))
        exact = bool((y_map == y_ref).all())
        print(f"[bitplane]   mapped {name} == unmapped cim_linear: {exact}")
        assert exact, f"{name}: mapped bitplane output diverged"

    cim_fq = CiMConfig(mode="fake_quant", a_bits=8, w_bits=8, adc_bits=5, rows=16, ste=False)
    y_map = np.asarray(execute_linear(x, w_q, fabric=fabric, cim=cim_fq))
    y_ref = np.asarray(cim_linear(x, w_q, cfg=cim_fq))
    err = float(np.abs(y_map - y_ref).max())
    print(f"[fake_quant] mapped q_proj vs unmapped (Pallas kernel path): maxerr={err:.2e}")
    assert err < 1e-4, err

    # --- multi-chip sharding ------------------------------------------------
    from repro.fabric.execute import execute_matmul

    cm1 = ChipMeshConfig(fabric=fabric)
    y_sh = np.asarray(execute_sharded_matmul(x, w_q, cm1, cim_bp))
    y_un = np.asarray(execute_matmul(x, w_q, fabric, cim_bp))
    exact = bool((y_sh == y_un).all())
    print(f"[shard]      1x1-mesh sharded q_proj == unsharded execute: {exact}")
    assert exact, "1x1-mesh sharded bitplane output diverged"

    # execution backends: the 1x1 mesh fits any host, so the REAL shard_map
    # device-mesh path must also be bit-exact vs the unsharded executor
    y_sm = np.asarray(execute_sharded_matmul(x, w_q, cm1, cim_bp, backend="shard_map"))
    exact = bool((y_sm == y_un).all())
    print(f"[shard]      1x1 shard_map backend == unsharded execute: {exact}")
    assert exact, "1x1 shard_map backend output diverged"

    cm4 = ChipMeshConfig(data=2, model=2, fabric=fabric)
    sps4 = shard_model(cfg, cm4, tokens=4, block_only=True)
    from repro.fabric import resolve_backend

    backend4 = resolve_backend(sps4[0], "auto")
    print(f"[shard]      2x2 mesh auto backend on {len(jax.devices())} "
          f"device(s): {backend4}")
    rep4 = sharded_fabric_report(sps4, cm4)
    print()
    print(render_markdown(rep4))
    t = rep4["totals"]
    assert t["crosschip_bits_per_pass"] > 0, "2x2 mesh should reduce-scatter"
    rep1 = sharded_fabric_report(shard_model(cfg, cm1, tokens=4, block_only=True), cm1)
    assert rep1["totals"]["crosschip_bits_per_pass"] == 0, "1 chip has no links"
    assert t["tiles_per_chip"] < rep1["totals"]["tiles_per_chip"], "K-split shrinks per-chip load"

    # --- whole-model fused forward (repro.fabric.program) -------------------
    from repro.fabric import compile_forward, measure_forward, per_layer_forward

    prog = compile_forward(cfg, cm1, cim=cim_bp, tokens=4, block_only=True)
    names = [sp.name for sp in prog.placements]
    print(f"\n[program]    block forward chain: {names} ({prog.backend})")
    xc = jax.random.normal(jax.random.PRNGKey(3), (prog.m, prog.placements[0].k))
    wsc = prog.random_weights(jax.random.PRNGKey(4))
    y_fused = np.asarray(prog(xc, wsc))
    y_loop = np.asarray(
        per_layer_forward(xc, wsc, prog.placements, cm1, cim_bp, backend="sequential")
    )
    exact = bool((y_fused == y_loop).all())
    print(f"[program]    fused 1x1 forward == per-layer loop: {exact}")
    assert exact, "fused forward diverged from the per-layer loop"
    if prog.backend == "shard_map":
        counts = prog.collective_counts(xc, wsc)
        print(f"[program]    collectives in the whole forward: {counts}")
        assert counts["all_gather"] <= 1, "fused forward must gather at most once"
    meas = measure_forward(prog, x=xc, weights=wsc, iters=1,
                           per_layer_backend="sequential")
    print(
        f"[program]    fused {meas.get('fused_s', float('nan'))*1e3:.3g} ms vs "
        f"per-layer loop {meas['per_layer_s']*1e3:.3g} ms wall; modeled link "
        f"{meas['modeled_link_s']*1e3:.3g} ms"
    )

    print("\nfabric_map: all chip-level checks passed.")


def graph_demo():
    """Full transformer block on the fabric with REAL model weights: fused
    graph forward vs the per-node reference, collective census vs budget,
    and the sibling-inclusive mesh rollup."""
    from repro.configs.base import ModelConfig
    from repro.fabric import (
        compile_graph_forward,
        per_node_forward,
        stack_block_weights,
        transformer_graph_weights,
    )
    from repro.models.transformer import init_transformer

    # a graph-eligible dense config: every K tile-aligns with the mesh and
    # q/kv heads divide the model axis, so the fused program runs on 2x2
    cfg = ModelConfig(
        name="graph-demo", family="dense", n_layers=2, d_model=64, vocab=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, pad_vocab_multiple=16,
        param_dtype="float32", compute_dtype="float32",
    )
    fabric = FabricConfig(mode="pair_sar", rows=16, cols=32, n_arrays=8)
    cim = CiMConfig(mode="bitplane", a_bits=4, w_bits=4, adc_bits=5, rows=16, ste=False)
    params = init_transformer(jax.random.PRNGKey(0), cfg)
    weights = transformer_graph_weights(params, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, cfg.d_model))

    meshes = [(1, 1)]
    if len(jax.devices()) >= 4:
        meshes.append((2, 2))
    for data, model in meshes:
        cm = ChipMeshConfig(data=data, model=model, fabric=fabric)
        prog = compile_graph_forward(cfg, cm, cim, tokens=8)
        print(f"[graph]      {data}x{model}: {len(prog.graph.nodes)} nodes "
              f"({len(prog.placements)} matmuls) on {prog.backend}")
        y = np.asarray(prog(x, weights))
        y_ref = np.asarray(
            per_node_forward(x, weights, prog.graph, prog.placements, cm, cim)
        )
        maxdiff = float(np.abs(y - y_ref).max())
        print(f"[graph]      fused logits vs per-node reference: maxdiff {maxdiff:.3g}")
        if (data, model) == (1, 1):
            assert maxdiff == 0.0, "1x1 fused graph must be bit-exact"
        else:
            assert maxdiff < 1e-4, maxdiff
        if prog.backend == "shard_map":
            counts = prog.collective_counts()
            budget = prog.collective_budget()
            print(f"[graph]      collectives {counts} == budget: {counts == budget}")
            assert counts == budget, (counts, budget)

        from repro.fabric import sharded_fabric_report

        rep = sharded_fabric_report(prog.placements, cm, graph=prog.graph)
        if (data, model) == meshes[-1]:
            print()
            print(render_markdown(rep))

    # --- scan-over-layers: the block traces ONCE ---------------------------
    import time

    cm1 = ChipMeshConfig(fabric=fabric)
    key = jax.random.PRNGKey(5)
    unrolled = compile_graph_forward(cfg, cm1, cim, tokens=8)
    scanned = compile_graph_forward(cfg, cm1, cim, tokens=8, scan_layers=True)
    ws_stacked = stack_block_weights(params, cfg)
    y_un = np.asarray(unrolled(x, weights, key=key))
    y_sc = np.asarray(scanned(x, ws_stacked, key=key))
    exact = bool((y_un == y_sc).all())
    print(f"[scan]       scanned ({scanned.n_blocks} lax.scan iterations) == "
          f"unrolled logits, noisy keys included: {exact}")
    assert exact, "scan-over-layers diverged from the unrolled program"
    for prog_t, tag in ((unrolled, "unrolled"), (scanned, "scanned")):
        args_t = prog_t._fused_args(x, prog_t.random_weights(key), key)
        t0 = time.perf_counter()
        prog_t._fused(True).lower(*args_t).compile()
        print(f"[scan]       {tag} trace+compile: {time.perf_counter() - t0:.2f}s")
    rep = sharded_fabric_report(
        scanned.placements, cm1, graph=scanned.graph, program=scanned
    )
    assert rep["graph"]["scan"]["n_blocks"] == cfg.n_layers
    print("\nfabric_map --graph: full-block fused forward checks passed.")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", action="store_true",
                    help="demo the full-transformer-block fused graph forward "
                    "with real init_transformer weights")
    if ap.parse_args().graph:
        graph_demo()
    else:
        main()
