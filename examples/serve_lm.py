"""Batched serving example: prefill + lock-step decode over a request batch,
with optional CiM-quantized inference (the paper's technique in serving).

  PYTHONPATH=src python examples/serve_lm.py [--cim]
"""

import argparse
import dataclasses
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

from repro.configs import ARCHS, reduced
from repro.core.cim_linear import CiMConfig
from repro.launch.serve import ServeSettings, serve_batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cim", action="store_true", help="CiM fake-quant inference")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen-len", type=int, default=24)
    args = ap.parse_args()

    cfg = reduced(ARCHS["smollm-135m"], n_layers=4, d_model=128, d_ff=384)
    if args.cim:
        cfg = dataclasses.replace(
            cfg, cim=CiMConfig(mode="fake_quant", adc_bits=8, rows=64, ste=False)
        )
    out = serve_batch(cfg, ServeSettings(batch=args.batch, prompt_len=32,
                                         gen_len=args.gen_len))
    mode = "CiM fake-quant" if args.cim else "exact"
    print(f"[{mode}] prefill {out['prefill_s']*1e3:.0f} ms, "
          f"decode {out['decode_tok_s']:.1f} tok/s")
    for i, row in enumerate(out["generated"][:2]):
        print(f"  request {i}: {row[:12].tolist()} ...")


if __name__ == "__main__":
    main()
