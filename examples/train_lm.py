"""End-to-end LM training driver (CPU-scale): a ~20M-param smollm-family
model for a few hundred steps with checkpoints, watchdog, and restart.

  PYTHONPATH=src python examples/train_lm.py [--steps 200] [--tiny]
"""

import argparse
import dataclasses
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

from repro.configs import ARCHS, reduced
from repro.ft.watchdog import run_with_restart
from repro.launch.train import TrainSettings, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--tiny", action="store_true", help="2-layer d=64 config")
    ap.add_argument("--ckpt-dir", default="results/example_ckpt")
    args = ap.parse_args()

    if args.tiny:
        cfg = reduced(ARCHS["smollm-135m"])
    else:  # ~20M params: same family, scaled to CPU budget
        cfg = reduced(
            ARCHS["smollm-135m"],
            n_layers=6, d_model=256, d_ff=768, vocab=8192,
            n_heads=4, n_kv_heads=2, head_dim=64,
        )
    n = cfg.n_params()
    print(f"training {cfg.name}-example ({n/1e6:.1f}M params) for {args.steps} steps")

    st = TrainSettings(
        steps=args.steps, batch=8, seq=256, lr=1e-3, warmup=20,
        ckpt_dir=args.ckpt_dir, ckpt_every=50, log_every=10,
    )

    def run(resume):
        out = train(cfg, st, resume=resume)
        print(f"loss: {out['first_loss']:.3f} -> {out['final_loss']:.3f} "
              f"({out['wall_s']:.0f}s, {st.batch * st.seq * args.steps / out['wall_s']:.0f} tok/s)")
        return st.steps

    run_with_restart(run, max_restarts=2)


if __name__ == "__main__":
    main()
