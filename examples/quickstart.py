"""Quickstart: the paper's pipeline end-to-end on one machine.

Trains the MNIST MLP in float, then evaluates it with every linear routed
through bit-plane CiM arrays digitized by the memory-immersed ADC — symmetric
SAR, asymmetric SAR (Fig. 4), and hybrid Flash+SAR — and prints the
area/energy ledger of Table I for the same operating points.

  PYTHONPATH=src python examples/quickstart.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

from repro.core.cim_linear import CiMConfig, digitization_stats
from repro.core.energy_area import energy_pj, table1
from repro.core.noise import AnalogEnv
from repro.train.mnist_mlp import evaluate, train_mlp


def main():
    print("== training float MLP on synthetic MNIST ==")
    params, float_acc = train_mlp(epochs=5)
    print(f"float test accuracy: {float_acc:.3f}\n")

    chip = dict(mode="bitplane", a_bits=4, w_bits=4, adc_bits=5, rows=16,
                a_signed=False, ste=False)
    configs = {
        "ideal (no CiM)": None,
        "CiM + symmetric SAR (5 cmp)": CiMConfig(search="sar", **chip),
        "CiM + asymmetric SAR (~3.7 cmp)": CiMConfig(search="sar_asym", **chip),
    }
    print("== inference through memory-immersed digitization ==")
    for name, cim in configs.items():
        acc = evaluate(params, cim, env=AnalogEnv(freq_hz=10e6, vdd=1.0), n_eval=1024)
        if cim is not None:
            d = digitization_stats(cim, 1024, 256, 128)
            e = energy_pj("in_memory_asym" if cim.search == "sar_asym" else "in_memory", 5)
            extra = f"  E/conv={e:.1f} pJ, E[cmp]={d['expected_comparisons_per_conversion']:.2f}"
        else:
            extra = ""
        print(f"  {name:34s} acc={acc:.3f}{extra}")

    print("\n== Table I (measured-anchor area/energy model) ==")
    for style, d in table1().items():
        print(f"  {style:10s} {d['tech']:>5s}  {d['area_um2']:>9.1f} um^2  {d['energy_pj']:>7.2f} pJ")


if __name__ == "__main__":
    main()
