"""Design-space sweep: ADC style x precision -> area / energy / latency /
MNIST accuracy — the full Fig. 7 exploration in one table.

  PYTHONPATH=src python examples/cim_design_space.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

from repro.core.cim_linear import CiMConfig
from repro.core.energy_area import area_um2, energy_pj, latency_cycles
from repro.train.mnist_mlp import evaluate, train_mlp


def main():
    params, float_acc = train_mlp(epochs=5)
    print(f"float accuracy: {float_acc:.3f}")
    print(f"{'style':18s} {'bits':>4s} {'area um2':>9s} {'E pJ':>7s} "
          f"{'lat cyc':>8s} {'accuracy':>8s}")
    for style in ("in_memory", "in_memory_asym", "in_memory_hybrid"):
        for bits in (3, 4, 5):
            cim = CiMConfig(
                mode="bitplane", a_bits=4, w_bits=4, adc_bits=bits, rows=16,
                a_signed=False, ste=False,
                search="sar_asym" if style == "in_memory_asym" else "sar",
            )
            acc = evaluate(params, cim, n_eval=512)
            print(f"{style:18s} {bits:4d} {area_um2(style, bits):9.1f} "
                  f"{energy_pj(style, bits):7.1f} {latency_cycles(style, bits):8.2f} "
                  f"{acc:8.3f}")


if __name__ == "__main__":
    main()
