"""CiM-quantized matmul: exactness regimes, quantization error, QAT gradients."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cim_linear import CiMConfig, cim_matmul, digitization_stats, quantize_symmetric


def _rand(shape, seed=0, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape) * scale


def test_bitplane_exact_on_chip_geometry():
    """16-row arrays + 5-bit ADC (the test chip) digitize exactly."""
    x, w = _rand((8, 64)), _rand((64, 16), 1)
    cfg = CiMConfig(mode="bitplane", a_bits=4, w_bits=4, adc_bits=5, rows=16, ste=False)
    y = cim_matmul(x, w, cfg)
    xi, sx = quantize_symmetric(x, 4, True)
    wi, sw = quantize_symmetric(w, 4, True, per_axis=-1)
    np.testing.assert_allclose(np.asarray(y), np.asarray((xi @ wi) * sx * sw), rtol=1e-5)


@pytest.mark.parametrize("rows,adc_bits", [(16, 5), (32, 6), (64, 7), (128, 8)])
def test_bitplane_exact_when_adc_resolves_rows(rows, adc_bits):
    x, w = _rand((4, rows * 2)), _rand((rows * 2, 8), 1)
    cfg = CiMConfig(
        mode="bitplane", a_bits=3, w_bits=3, adc_bits=adc_bits, rows=rows, ste=False
    )
    y = cim_matmul(x, w, cfg)
    xi, sx = quantize_symmetric(x, 3, True)
    wi, sw = quantize_symmetric(w, 3, True, per_axis=-1)
    np.testing.assert_allclose(np.asarray(y), np.asarray((xi @ wi) * sx * sw), rtol=1e-5)


def test_bitplane_lossy_when_adc_underresolves():
    """2^B < rows: quantization error appears but stays bounded by theory."""
    x, w = _rand((4, 128)), _rand((128, 8), 1)
    cfg = CiMConfig(mode="bitplane", a_bits=4, w_bits=4, adc_bits=5, rows=64, ste=False)
    y = cim_matmul(x, w, cfg)
    xi, sx = quantize_symmetric(x, 4, True)
    wi, sw = quantize_symmetric(w, 4, True, per_axis=-1)
    ref = (xi @ wi) * sx * sw
    err = np.abs(np.asarray(y - ref))
    assert err.max() > 0  # lossy
    # error bound: per plane-pair & tile, code error < LSB -> counts err < R/2^B
    t, planes = 2, 4 * 4
    wa = np.abs(np.array([1, 2, 4, -8]))
    bound = (64 / 32) * (wa.sum() ** 2) * t * float(sx) * float(np.max(sw))
    assert err.max() <= bound


def test_unsigned_activations_paper_mode():
    """Post-ReLU unsigned planes (the chip's single-ended mode)."""
    x = jnp.abs(_rand((8, 64)))
    w = _rand((64, 8), 1)
    cfg = CiMConfig(
        mode="bitplane", a_bits=4, w_bits=4, adc_bits=5, rows=16,
        a_signed=False, ste=False,
    )
    y = cim_matmul(x, w, cfg)
    xi, sx = quantize_symmetric(x, 4, False)
    wi, sw = quantize_symmetric(w, 4, True, per_axis=-1)
    np.testing.assert_allclose(np.asarray(y), np.asarray((xi @ wi) * sx * sw), rtol=1e-5)


def test_fake_quant_error_shrinks_with_adc_bits():
    x, w = _rand((16, 256)), _rand((256, 32), 1)
    ref = x @ w
    errs = []
    for b in (4, 6, 8, 10):
        cfg = CiMConfig(mode="fake_quant", adc_bits=b, rows=16, ste=False)
        y = cim_matmul(x, w, cfg)
        errs.append(float(jnp.abs(y - ref).max()))
    assert errs[0] > errs[1] > errs[2] > errs[3]


def test_ste_gradients_equal_exact_matmul():
    x, w = _rand((4, 64)), _rand((64, 8), 1)
    cfg = CiMConfig(mode="fake_quant", ste=True)
    g_cim = jax.grad(lambda w: cim_matmul(x, w, cfg).sum())(w)
    g_ref = jax.grad(lambda w: (x @ w).sum())(w)
    np.testing.assert_allclose(np.asarray(g_cim), np.asarray(g_ref), atol=1e-6)


def test_exact_mode_is_plain_matmul():
    x, w = _rand((4, 32)), _rand((32, 8), 1)
    cfg = CiMConfig(mode="exact")
    np.testing.assert_allclose(
        np.asarray(cim_matmul(x, w, cfg)), np.asarray(x @ w), rtol=1e-6
    )


def test_stats_accounting():
    cfg = CiMConfig(mode="bitplane", a_bits=4, w_bits=4, adc_bits=5, rows=16, ste=False)
    x, w = _rand((2, 32)), _rand((32, 4), 1)
    y, stats = cim_matmul(x, w, cfg, return_stats=True)
    # conversions = A*W*M*T*N = 4*4*2*2*4
    assert int(stats.conversions) == 4 * 4 * 2 * 2 * 4
    # symmetric SAR: 5 comparisons per conversion
    assert int(stats.comparisons) == int(stats.conversions) * 5
    d = digitization_stats(CiMConfig(search="sar_asym"), 2, 32, 4)
    assert 3.5 <= d["expected_comparisons_per_conversion"] <= 3.9


def test_batched_inputs():
    x = _rand((3, 5, 64))
    w = _rand((64, 8), 1)
    cfg = CiMConfig(mode="fake_quant", ste=False)
    y = cim_matmul(x, w, cfg)
    assert y.shape == (3, 5, 8)
