"""Continuous batching (``repro.fabric.autotune``): bucketed LRU program
cache keying/eviction, padded-vs-unpadded bit-exactness (noisy ADC included
— pad rows must not consume noise-key draws), pad-row exclusion from the
conversion/comparison stats and obs counter totals, bucket hit/miss/pad
accounting (a ragged batch landing in a bucket is a hit, NOT a
``ragged_batch`` fallback; only a too-large batch records ``no_bucket``),
and the cost-model autotuner (GQA-violating mesh rejection, plan cost never
above the default mesh's). ``tests/conftest.py`` forces 8 host devices."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.obs as obs
from repro.configs.base import ModelConfig
from repro.core.cim_linear import CiMConfig
from repro.fabric import (
    AutotunePlan,
    BucketedGraphCache,
    ChipMeshConfig,
    FabricConfig,
    autotune_plan,
    autotune_section,
    request_histogram,
    transformer_graph_weights,
)
from repro.models.transformer import init_transformer

FB = FabricConfig(mode="pair_sar", rows=16, cols=32, n_arrays=8)
CIM_BP = CiMConfig(mode="bitplane", a_bits=4, w_bits=4, adc_bits=5, rows=16, ste=False)
NOISY = dataclasses.replace(CIM_BP, comparator_sigma=0.05)

# graph-eligible on a 2x2 mesh: every K tile-aligns (64/128 % (2*16) == 0)
# and q/kv heads (4/2) divide the model axis
CFG = ModelConfig(
    name="autotune-test", family="dense", n_layers=1, d_model=64, vocab=64,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, pad_vocab_multiple=16,
    param_dtype="float32", compute_dtype="float32",
)
MESH = ChipMeshConfig(data=2, model=2, fabric=FB)
SEQ = 4


@pytest.fixture(scope="module", autouse=True)
def _drop_compiled_programs():
    """This module compiles many bucketed graph-program variants; release
    their executables when it finishes so the later (also compile-heavy)
    suite modules don't accumulate on top of them in the one shared
    process."""
    yield
    jax.clear_caches()


@pytest.fixture(scope="module")
def real_weights():
    params = init_transformer(jax.random.PRNGKey(0), CFG)
    return transformer_graph_weights(params, CFG)


def _x(b: int, seed: int = 0):
    return jax.random.normal(jax.random.PRNGKey(seed), (b, SEQ, CFG.d_model))


# ---------------------------------------------------------------------------
# histogram + bucket validation
# ---------------------------------------------------------------------------


def test_request_histogram_collapses_and_validates():
    assert request_histogram([3, 1, 3, 4]) == {1: 1, 3: 2, 4: 1}
    with pytest.raises(ValueError, match=">= 1"):
        request_histogram([2, 0])


def test_bucket_boundaries_must_be_data_multiples():
    with pytest.raises(ValueError, match="multiple of the data axis"):
        BucketedGraphCache(CFG, MESH, CIM_BP, buckets=(3,), seq=SEQ)
    with pytest.raises(ValueError, match="at least one bucket"):
        BucketedGraphCache(CFG, MESH, CIM_BP, buckets=(), seq=SEQ)
    cache = BucketedGraphCache(CFG, MESH, CIM_BP, buckets=(4, 2, 4), seq=SEQ)
    assert cache.buckets == (2, 4)  # sorted, deduped
    assert cache.bucket_for(1) == 2
    assert cache.bucket_for(2) == 2
    assert cache.bucket_for(3) == 4
    assert cache.bucket_for(5) is None


# ---------------------------------------------------------------------------
# LRU keying / eviction
# ---------------------------------------------------------------------------


def test_lru_keying_and_eviction():
    cache = BucketedGraphCache(
        CFG, MESH, CIM_BP, buckets=(2, 4, 6), seq=SEQ, capacity=2
    )
    p2 = cache.program_for(2)
    p4 = cache.program_for(4)
    assert cache.compiles == 2 and cache.evictions == 0
    # a repeat touch is a cache hit on the SAME compiled program object
    assert cache.program_for(2) is p2
    assert cache.program_for(4) is p4
    assert cache.compiles == 2
    # capacity 2: inserting bucket 6 evicts the least recently used (2,
    # because 4 was touched last)
    cache.program_for(6)
    assert cache.compiles == 3 and cache.evictions == 1
    assert cache.program_for(4) is p4  # still resident
    assert cache.compiles == 3
    # bucket 2 was evicted — coming back recompiles a NEW program
    assert cache.program_for(2) is not p2
    assert cache.compiles == 4 and cache.evictions == 2
    # noisy ADC keys a separate cache entry at the same padded batch
    cache.program_for(2, noisy=True)
    assert cache.compiles == 5
    assert cache.stats()["resident_programs"] == 2


# ---------------------------------------------------------------------------
# bit-exactness of the padded bucketed path
# ---------------------------------------------------------------------------


def test_ragged_bucket_bit_exact_noiseless(real_weights):
    """B=3 on the 2x2 mesh: padded to the 4-bucket, served fused, sliced —
    bit-exact to the unpadded per-node reference (the acceptance shape)."""
    cache = BucketedGraphCache(CFG, MESH, CIM_BP, buckets=(4,), seq=SEQ)
    prog = cache.program_for(4)
    assert prog.backend == "shard_map"
    y = np.asarray(cache(_x(3), real_weights))
    y_ref = np.asarray(prog.reference_forward(_x(3), real_weights))
    assert y.shape == y_ref.shape
    assert (y == y_ref).all()


def test_ragged_bucket_bit_exact_noisy_adc(real_weights):
    """Noisy ADC: pad rows must not consume noise draws — per-row keys
    derive each row's comparator draws from its GLOBAL row id, so padding
    3 -> 4 leaves rows 0..2 with exactly the draws of the unpadded run."""
    nk = jax.random.PRNGKey(7)
    cache = BucketedGraphCache(CFG, MESH, NOISY, buckets=(4,), seq=SEQ)
    y = np.asarray(cache(_x(3), real_weights, key=nk))
    y_ref = np.asarray(
        cache.program_for(4, noisy=True).reference_forward(
            _x(3), real_weights, key=nk
        )
    )
    assert (y == y_ref).all()


def test_pad_rows_do_not_shift_noise_draws():
    """The draw-invariance property the bucketed path rests on, tested at
    the executor level: a row's comparator draws derive from its GLOBAL row
    id (``fold_in(cmp_key, row_offset + i)``), so truncating the batch or
    slicing it at an offset cannot re-deal any surviving row's draws."""
    from repro.core.cim_linear import quantize_symmetric
    from repro.fabric.tiles import column_tile_matmul

    key = jax.random.PRNGKey(5)
    x_int, _ = quantize_symmetric(
        jax.random.normal(jax.random.PRNGKey(1), (6, 32)), 4, True
    )
    w_int, _ = quantize_symmetric(
        jax.random.normal(jax.random.PRNGKey(2), (32, 24)), 4, True, per_axis=-1
    )
    y6, _ = column_tile_matmul(x_int, w_int, NOISY, cols=8, key=key)
    # shorter batch, same global rows 0..3
    y4, _ = column_tile_matmul(x_int[:4], w_int, NOISY, cols=8, key=key)
    np.testing.assert_array_equal(np.asarray(y6)[:4], np.asarray(y4))
    # offset slice, same global rows 2..5 (a data shard starting at row 2)
    y_off, _ = column_tile_matmul(
        x_int[2:], w_int, NOISY, cols=8, key=key, row_offset=2
    )
    np.testing.assert_array_equal(np.asarray(y6)[2:], np.asarray(y_off))
    # the noise is real: a different key must change the noisy result
    y_other, _ = column_tile_matmul(
        x_int, w_int, NOISY, cols=8, key=jax.random.PRNGKey(99)
    )
    assert (np.asarray(y6) != np.asarray(y_other)).any()


# ---------------------------------------------------------------------------
# pad-row exclusion from stats / counters
# ---------------------------------------------------------------------------


def test_padded_stats_equal_unpadded_fused(real_weights):
    """B=2 is mesh-aligned, so it can run fused both unpadded (direct) and
    padded 2 -> 4 (via the bucket cache): logits AND CimStats must match —
    pad rows contribute zero conversions/comparisons to the report."""
    cache = BucketedGraphCache(CFG, MESH, CIM_BP, buckets=(4,), seq=SEQ)
    prog_direct = cache.program_for(4)  # same program, different batch
    y_pad, st_pad = cache(_x(2), real_weights, return_stats=True)
    y_ref, st_ref = prog_direct(_x(2), real_weights, return_stats=True)
    assert (np.asarray(y_pad) == np.asarray(y_ref)).all()
    assert int(st_pad.conversions) == int(st_ref.conversions)
    assert int(st_pad.comparisons) == int(st_ref.comparisons)
    assert cache.pad_waste_rows == 2


def test_padded_obs_totals_equal_unpadded(real_weights):
    """The metric totals the fused path records (conversions, link bits,
    tokens in the span) account only the 3 real rows of a padded 3 -> 4
    request — both are per-row-constant, so they must sit at exactly 3/4 of
    the aligned 4-row run's totals."""
    cache = BucketedGraphCache(CFG, MESH, CIM_BP, buckets=(4,), seq=SEQ)
    with obs.tracing() as tr, obs.collecting():
        cache(_x(3), real_weights)
        conv_pad = obs.get_value("fabric_conversions_total")
        link_pad = obs.get_value("fabric_link_bits_total")
    (span,) = [s for s in tr.spans if s["name"] == "fabric.graph.forward"]
    assert span["attrs"]["tokens"] == 3 * SEQ  # NOT 4 * SEQ
    with obs.collecting():
        cache(_x(4), real_weights)  # aligned in-bucket: no padding
        conv_4 = obs.get_value("fabric_conversions_total")
        link_4 = obs.get_value("fabric_link_bits_total")
    assert conv_pad > 0 and link_pad > 0
    assert conv_pad * 4 == conv_4 * 3
    assert link_pad * 4 == link_4 * 3


def test_bucket_hit_miss_and_fallback_accounting(real_weights):
    """Ragged-in-bucket = hit (0 ragged_batch fallbacks); larger than every
    bucket = miss with the pinned ``no_bucket`` reason."""
    cache = BucketedGraphCache(CFG, MESH, CIM_BP, buckets=(4,), seq=SEQ)
    with obs.tracing() as tr, obs.collecting():
        cache(_x(3), real_weights)  # ragged, fits the 4-bucket
        assert obs.get_value("fabric_bucket_hits_total") == 1.0
        assert obs.get_value("fabric_pad_waste_rows_total") == 1.0
        assert obs.get_value("fabric_bucket_misses_total") == 0.0
        assert obs.get_value(
            "fabric_fallback_total", reason=obs.REASON_RAGGED_BATCH
        ) == 0.0
        assert obs.get_value("fabric_requests_total", path="fused") == 1.0

        cache(_x(6), real_weights)  # exceeds every bucket
        assert obs.get_value("fabric_bucket_misses_total") == 1.0
        assert obs.get_value(
            "fabric_fallback_total", reason=obs.REASON_NO_BUCKET
        ) == 1.0
        assert obs.get_value("fabric_requests_total", path="fused") == 1.0
    ev = [e for e in tr.events if e["name"] == "fabric.fallback"]
    assert [e["attrs"]["reason"] for e in ev] == [obs.REASON_NO_BUCKET]
    assert "exceeds largest bucket 4" in ev[0]["attrs"]["detail"]
    assert cache.stats()["hits"] == 1
    assert cache.stats()["misses"] == 1
    assert cache.stats()["pad_waste_rows"] == 1


def test_no_bucket_fallback_result_matches_reference(real_weights):
    cache = BucketedGraphCache(CFG, MESH, CIM_BP, buckets=(2,), seq=SEQ)
    y = np.asarray(cache(_x(3), real_weights))
    y_ref = np.asarray(
        cache.program_for(2).reference_forward(_x(3), real_weights)
    )
    assert (y == y_ref).all()


# ---------------------------------------------------------------------------
# autotuner
# ---------------------------------------------------------------------------


def test_autotune_rejects_gqa_violating_meshes():
    """On 8 chips, meshes with model axis 4 or 8 violate the head-group
    constraints (n_kv_heads=2 % 4, n_heads=4 % 8) — the plan's model axis
    must divide the KV heads, and the infeasible default (1, 8) anchors the
    baseline at the cheapest feasible single-bucket plan instead."""
    plan = autotune_plan(
        CFG, {1: 2, 3: 1}, 8, FB, seq=SEQ, cim=CIM_BP, default_mesh=(1, 8)
    )
    assert CFG.n_kv_heads % plan.model == 0
    assert plan.model in (1, 2)
    assert plan.expected_latency_s <= plan.baseline_latency_s
    assert plan.baseline_latency_s < float("inf")
    assert plan.speedup_vs_baseline >= 1.0


def test_autotune_no_feasible_mesh_raises():
    # 16 chips on the 8-device host: every (data, model) factorization
    # fails graph_eligibility's device-count check
    with pytest.raises(ValueError, match="no feasible"):
        autotune_plan(CFG, {2: 1}, 16, FB, seq=SEQ, cim=CIM_BP)


def test_autotune_plan_cost_le_default_and_deterministic():
    hist = request_histogram([3, 1, 2, 3])
    a = autotune_plan(CFG, hist, 4, FB, seq=SEQ, cim=CIM_BP, default_mesh=(2, 2))
    b = autotune_plan(CFG, hist, 4, FB, seq=SEQ, cim=CIM_BP, default_mesh=(2, 2))
    assert isinstance(a, AutotunePlan)
    assert a == b  # frozen dataclass equality — the search is deterministic
    assert a.expected_latency_s <= a.baseline_latency_s
    assert a.searched > 0
    # every bucket boundary is a positive multiple of the chosen data axis
    assert all(bb > 0 and bb % a.data == 0 for bb in a.buckets)
    # the largest observed batch always fits the largest bucket
    assert a.buckets[-1] >= max(hist)


def test_autotune_section_shape():
    plan = autotune_plan(CFG, {2: 1}, 4, FB, seq=SEQ, cim=CIM_BP)
    cache = BucketedGraphCache(
        CFG, ChipMeshConfig(data=plan.data, model=plan.model, fabric=FB),
        CIM_BP, buckets=plan.buckets, seq=SEQ,
    )
    sec = autotune_section(plan, cache)
    assert sec["mesh"] == f"{plan.data}x{plan.model}"
    assert sec["buckets"] == list(plan.buckets)
    assert sec["speedup_vs_baseline"] >= 1.0
    assert sec["cache"]["buckets"] == list(plan.buckets)
    assert autotune_section(plan).get("cache") is None
