"""Fused flash-attention Pallas kernel vs plain-softmax oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ref import flash_attention_ref


@pytest.mark.parametrize(
    "b,h,kv,sq,sk,hd,causal",
    [
        (2, 4, 2, 256, 256, 64, True),    # GQA g=2
        (1, 8, 8, 128, 384, 32, True),    # MHA, rectangular
        (2, 4, 1, 256, 256, 64, False),   # MQA, full attention
        (1, 2, 2, 512, 512, 128, True),   # MXU-aligned head dim
    ],
)
def test_flash_vs_ref(b, h, kv, sq, sk, hd, causal):
    q = jax.random.normal(jax.random.PRNGKey(0), (b, h, sq, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, kv, sk, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, kv, sk, hd))
    o_k = flash_attention_pallas(q, k, v, causal=causal, interpret=True)
    o_r = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=3e-5, rtol=1e-4)


def test_flash_causal_blocks_skipped():
    """Causal mode must produce the same result with any block partition —
    including the dynamic-upper-bound skipping path."""
    b, h, sq, hd = 1, 2, 512, 64
    q = jax.random.normal(jax.random.PRNGKey(3), (b, h, sq, hd))
    k = jax.random.normal(jax.random.PRNGKey(4), (b, h, sq, hd))
    v = jax.random.normal(jax.random.PRNGKey(5), (b, h, sq, hd))
    outs = [
        flash_attention_pallas(q, k, v, causal=True, block_q=bq, block_k=bk, interpret=True)
        for bq, bk in ((128, 128), (256, 128), (128, 256), (512, 512))
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o), atol=3e-5, rtol=1e-4)


def test_flash_bf16():
    b, h, sq, hd = 1, 4, 256, 64
    mk = lambda s, sh: jax.random.normal(jax.random.PRNGKey(s), sh, jnp.bfloat16)
    q, k, v = mk(0, (b, h, sq, hd)), mk(1, (b, h, sq, hd)), mk(2, (b, h, sq, hd))
    o_k = flash_attention_pallas(q, k, v, causal=True, interpret=True)
    o_r = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(o_k, np.float32), np.asarray(o_r, np.float32), atol=2e-2, rtol=2e-2
    )


def test_flash_rejects_bad_shapes():
    q = jnp.zeros((1, 3, 128, 32))
    k = jnp.zeros((1, 2, 128, 32))
    with pytest.raises(ValueError):
        flash_attention_pallas(q, k, k, interpret=True)
