"""Checkpointing: roundtrip, atomicity, GC, elastic restore, async."""

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import Checkpointer, latest_step, restore, save


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (8, 16)),
        "nested": {"b": jnp.arange(10, dtype=jnp.int32), "c": jnp.float32(3.5)},
    }


def test_roundtrip(tmp_path):
    t = _tree()
    save(tmp_path, 5, t)
    assert latest_step(tmp_path) == 5
    back = restore(tmp_path, 5, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_last_gc(tmp_path):
    t = _tree()
    for s in range(6):
        save(tmp_path, s, t, keep_last=3)
    steps = sorted(int(p.name.split("_")[1]) for p in Path(tmp_path).glob("step_*"))
    assert steps == [3, 4, 5]


def test_atomic_no_tmp_left(tmp_path):
    save(tmp_path, 1, _tree())
    assert not list(Path(tmp_path).glob("*.tmp"))
    assert (Path(tmp_path) / "step_000000001" / "manifest.json").exists()


def test_manifest_records_global_shapes(tmp_path):
    save(tmp_path, 2, _tree())
    man = json.loads((Path(tmp_path) / "step_000000002" / "manifest.json").read_text())
    assert man["keys"]["a"]["shape"] == [8, 16]


def test_async_checkpointer(tmp_path):
    c = Checkpointer(tmp_path, keep_last=2)
    c.save_async(1, _tree())
    c.save_async(2, _tree(1))  # waits for the first internally
    c.wait()
    assert latest_step(tmp_path) == 2


def test_elastic_restore_other_sharding(tmp_path):
    """Restore under a different mesh/sharding (elastic scaling)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    t = _tree()
    save(tmp_path, 1, t)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    sh = {
        "a": NamedSharding(mesh, P("data", None)),
        "nested": {
            "b": NamedSharding(mesh, P()),
            "c": NamedSharding(mesh, P()),
        },
    }
    back = restore(tmp_path, 1, t, shardings=sh)
    np.testing.assert_array_equal(np.asarray(back["a"]), np.asarray(t["a"]))
    assert back["a"].sharding.spec == P("data", None)


def test_restore_missing_step_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore(tmp_path, 99, _tree())
