"""Observability (``repro.obs``): span/tracer scoping, the metrics
registry and its Prometheus exposition, JSONL sinks, the pinned fallback
reason taxonomy, and — the load-bearing part — the neutrality guarantees:
fused collective censuses and bit-exact outputs must be identical with
observability on or off. ``tests/conftest.py`` forces 8 host devices."""

import json
import time

import jax
import numpy as np
import pytest

from repro import obs
from repro.core.cim_linear import CiMConfig
from repro.fabric import (
    ChipMeshConfig,
    FabricConfig,
    compile_forward,
    compile_graph_forward,
    link_validation,
    map_matmul,
    resolve_backend,
    shard_placement,
    transformer_graph_weights,
)
from repro.obs import trace as obs_trace

FB = FabricConfig(mode="pair_sar", rows=16, cols=32, n_arrays=8)
NOISY = CiMConfig(
    mode="bitplane", a_bits=4, w_bits=4, adc_bits=5, rows=16, ste=False,
    comparator_sigma=0.05,
)
SHAPES = [("l0", 4, 64, 64), ("l1", 4, 64, 96), ("l2", 4, 96, 32)]


def chain(cm, cim=NOISY, shapes=SHAPES):
    return [
        shard_placement(map_matmul(name, m, k, n, cm.fabric, cim=cim), cm)
        for name, m, k, n in shapes
    ]


# ---------------------------------------------------------------------------
# tracing
# ---------------------------------------------------------------------------


def test_span_disabled_returns_shared_noop_singleton():
    """Outside any tracing block, span() is the zero-allocation null path."""
    assert not obs.enabled()
    s1 = obs.span("anything", layer="q")
    s2 = obs.span("else")
    assert s1 is s2  # one shared singleton, no per-call allocation
    with s1 as sp:
        sp.set(tiles=4)  # all methods are no-ops
    obs.event("dropped.event", x=1)  # silently dropped


def test_tracing_records_spans_events_and_nesting_composes():
    with obs.tracing() as outer:
        with obs.tracing() as inner:
            with obs.span("fabric.demo", layer="l0") as sp:
                sp.set(backend="sequential")
            obs.event("fabric.fallback", reason="ragged_batch")
        # after the inner block closes, only the outer tracer listens
        obs.event("outer.only")
    for tr in (outer, inner):
        (rec,) = tr.spans
        assert rec["kind"] == "span" and rec["name"] == "fabric.demo"
        assert rec["attrs"] == {"layer": "l0", "backend": "sequential"}
        assert rec["duration_s"] >= 0
    assert [e["name"] for e in inner.events] == ["fabric.fallback"]
    assert [e["name"] for e in outer.events] == ["fabric.fallback", "outer.only"]
    assert not obs.enabled()


def test_disabled_span_overhead_is_bounded():
    """The disabled path must stay cheap enough to leave in hot loops."""
    t0 = time.perf_counter()
    for _ in range(10_000):
        with obs.span("hot.loop", i=0):
            pass
    elapsed = time.perf_counter() - t0
    # generous absolute bound: 10k disabled spans in well under a second
    assert elapsed < 1.0, f"10k disabled spans took {elapsed:.3f}s"


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------


def test_metrics_registry_counters_gauges_histograms():
    with obs.collecting() as reg:
        obs.inc("fabric_requests_total", path="fused")
        obs.inc("fabric_requests_total", 2, path="fallback")
        obs.set_gauge("fabric_link_clock_calibration", 2.9e4)
        obs.observe("serve_prefill_seconds", 0.05)
        obs.observe("serve_prefill_seconds", 0.5)
        assert obs.active()
        assert obs.get_value("fabric_requests_total", path="fused") == 1.0
        assert obs.get_value("fabric_requests_total", path="fallback") == 2.0
        assert obs.get_value("fabric_link_clock_calibration") == 2.9e4
        assert obs.get_value("never_registered") == 0.0
    assert not obs.active()
    assert obs.get_value("fabric_requests_total", path="fused") == 0.0  # off
    assert reg.names() == [
        "fabric_link_clock_calibration",
        "fabric_requests_total",
        "serve_prefill_seconds",
    ]
    assert reg.histogram("serve_prefill_seconds").count() == 2
    assert reg.histogram("serve_prefill_seconds").sum() == pytest.approx(0.55)


def test_metrics_registry_rejects_misuse():
    reg = obs.MetricsRegistry()
    with pytest.raises(ValueError, match="cannot decrease"):
        reg.counter("c").inc(-1)
    reg.counter("taken")
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("taken")


def test_prometheus_text_exposition_format():
    reg = obs.MetricsRegistry()
    reg.counter("fabric_fallback_total", help="Fallbacks.").inc(
        reason="ragged_batch"
    )
    reg.histogram("lat_seconds", buckets=(0.1, 1.0)).observe(0.05)
    text = reg.prometheus_text()
    assert "# HELP fabric_fallback_total Fallbacks." in text
    assert "# TYPE fabric_fallback_total counter" in text
    assert 'fabric_fallback_total{reason="ragged_batch"} 1' in text
    assert "# TYPE lat_seconds histogram" in text
    # cumulative buckets with an auto-appended +Inf bound
    assert 'lat_seconds_bucket{le="0.1"} 1' in text
    assert 'lat_seconds_bucket{le="+Inf"} 1' in text
    assert "lat_seconds_count 1" in text
    assert text.endswith("\n")


# ---------------------------------------------------------------------------
# sinks
# ---------------------------------------------------------------------------


def test_jsonl_sink_streams_parse_clean(tmp_path):
    path = tmp_path / "trace.jsonl"
    with obs.tracing(jsonl=str(path)) as tr:
        with obs.span("fabric.demo", m=4):
            pass
        obs.event("fabric.fallback", reason="ragged_batch")
    records = obs.read_jsonl(str(path))
    assert len(records) == len(tr.spans) + len(tr.events) == 2
    assert {r["name"] for r in records} == {"fabric.demo", "fabric.fallback"}
    path.write_text(json.dumps(records[0]) + "\nnot json\n")
    with pytest.raises(ValueError):
        obs.read_jsonl(str(path))


def test_write_prometheus_sink(tmp_path):
    reg = obs.MetricsRegistry()
    reg.counter("fabric_matmuls_total").inc(3)
    out = tmp_path / "metrics.prom"
    obs.write_prometheus(reg, str(out))
    assert "fabric_matmuls_total 3" in out.read_text()


# ---------------------------------------------------------------------------
# fallback taxonomy (wire format — strings are pinned, not prose)
# ---------------------------------------------------------------------------


def test_fallback_reason_strings_are_pinned():
    assert obs.REASON_RAGGED_BATCH == "ragged_batch"
    assert obs.REASON_INSUFFICIENT_DEVICES == "insufficient_devices"
    assert obs.REASON_REPLICATION_FALLBACK == "replication_fallback"
    assert obs.REASON_REQUESTED_SEQUENTIAL == "requested_sequential"
    assert obs.REASON_INELIGIBLE == "ineligible"
    assert obs.REASON_NO_BUCKET == "no_bucket"
    assert obs.FALLBACK_REASONS == (
        "ragged_batch", "insufficient_devices", "replication_fallback",
        "requested_sequential", "ineligible", "no_bucket",
    )
    assert obs.classify_fallback(["host has 1 jax device(s) < 4 chips"]) \
        == "insufficient_devices"
    assert obs.classify_fallback(["replication fallbacks leave realized "
                                  "splits 1x1 != mesh 2x2"]) \
        == "replication_fallback"
    assert obs.classify_fallback(["anything else"]) == "ineligible"


def test_insufficient_devices_fallback_recorded():
    """A 4x4 mesh (16 chips) on the 8-device host must auto-fall back with
    the canonical insufficient_devices reason and a device-count detail."""
    cm = ChipMeshConfig(data=4, model=4, fabric=FB)
    sp = shard_placement(map_matmul("l", 16, 256, 64, FB, cim=NOISY), cm)
    with obs.tracing() as tr, obs.collecting():
        assert resolve_backend(sp, "auto") == "sequential"
        assert obs.get_value(
            "fabric_fallback_total", reason="insufficient_devices"
        ) == 1.0
    (ev,) = [e for e in tr.events if e["name"] == "fabric.fallback"]
    assert ev["attrs"]["reason"] == "insufficient_devices"
    assert "jax device" in ev["attrs"]["detail"]


def test_explicit_sequential_request_records_no_fallback():
    """backend="sequential" is a request, not a degradation."""
    cm = ChipMeshConfig(data=2, model=2, fabric=FB)
    sp = shard_placement(map_matmul("l", 4, 64, 64, FB, cim=NOISY), cm)
    with obs.collecting():
        assert resolve_backend(sp, "sequential") == "sequential"
        assert obs.get_value("fabric_fallback_total") == 0.0
        for reason in obs.FALLBACK_REASONS:
            assert obs.get_value("fabric_fallback_total", reason=reason) == 0.0


def test_ragged_batch_fallback_counted_exactly_once():
    """The CI gate's exact semantics: an aligned fused request records 0
    ragged_batch fallbacks, a ragged one records exactly 1 (at the program
    level — the inner per-layer loop must not double-count)."""
    cm = ChipMeshConfig(data=2, model=2, fabric=FB)
    prog = compile_forward(chain(cm), cm, NOISY)
    assert prog.backend == "shard_map"
    ws = prog.random_weights(jax.random.PRNGKey(1))
    nk = jax.random.PRNGKey(7)
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64))
    with obs.tracing() as tr, obs.collecting():
        prog(x, ws, key=nk)  # aligned: fused path
        assert obs.get_value("fabric_fallback_total",
                             reason="ragged_batch") == 0.0
        assert obs.get_value("fabric_requests_total", path="fused") == 1.0
        prog(x[:3], ws, key=nk)  # 3 rows % data axis 2 != 0
        assert obs.get_value("fabric_fallback_total",
                             reason="ragged_batch") == 1.0
        assert obs.get_value("fabric_requests_total", path="fallback") == 1.0
    (ev,) = [e for e in tr.events if e["name"] == "fabric.fallback"]
    assert ev["attrs"]["reason"] == "ragged_batch"
    assert "batch rows 3" in ev["attrs"]["detail"]


def test_sharding_replication_fallback_emits_obs_records():
    from jax.sharding import Mesh
    from repro.launch.shardings import spec_for

    devs = np.array(jax.devices()[:2]).reshape(1, 2)
    mesh = Mesh(devs, ("data", "model"))
    with obs.tracing() as tr, obs.collecting():
        spec_for(mesh, (16, 33), ("fsdp", "tp"), "wq")  # 33 % 2 != 0
        assert obs.get_value("sharding_fallback_total") == 1.0
    (ev,) = [e for e in tr.events if e["name"] == "sharding.fallback"]
    assert "wq" in ev["attrs"]["detail"]


# ---------------------------------------------------------------------------
# neutrality: observability provably does not perturb compiled programs
# ---------------------------------------------------------------------------


def test_obs_does_not_change_fused_chain_census_or_outputs():
    cm = ChipMeshConfig(data=2, model=2, fabric=FB)
    prog = compile_forward(chain(cm), cm, NOISY)
    assert prog.backend == "shard_map"
    ws = prog.random_weights(jax.random.PRNGKey(1))
    nk = jax.random.PRNGKey(7)
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64))
    census_off = prog.collective_counts(x, ws, key=nk)
    y_off = np.asarray(prog(x, ws, key=nk))
    with obs.tracing(), obs.collecting():
        census_on = prog.collective_counts(x, ws, key=nk)
        y_on = np.asarray(prog(x, ws, key=nk))
    assert census_on == census_off
    assert (y_on == y_off).all()


def test_obs_does_not_change_fused_graph_logits_1x1_noisy():
    from repro.configs.base import ModelConfig
    from repro.models.transformer import init_transformer

    cfg = ModelConfig(
        name="obs-neutrality", family="dense", n_layers=1, d_model=64,
        vocab=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
        pad_vocab_multiple=16, param_dtype="float32",
        compute_dtype="float32",
    )
    cm1 = ChipMeshConfig(fabric=FB)
    prog = compile_graph_forward(cfg, cm1, NOISY, tokens=8)
    assert prog.backend == "shard_map"  # the graph fuses even on 1x1
    params = init_transformer(jax.random.PRNGKey(0), cfg)
    ws = transformer_graph_weights(params, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, cfg.d_model))
    nk = jax.random.PRNGKey(7)
    census_off = prog.collective_counts(key=nk)
    y_off = np.asarray(prog(x, ws, key=nk))
    with obs.tracing() as tr, obs.collecting():
        census_on = prog.collective_counts(key=nk)
        y_on = np.asarray(prog(x, ws, key=nk))
    assert census_on == census_off
    assert (y_on == y_off).all()
    assert any(s["name"] == "fabric.graph.forward" for s in tr.spans)


# ---------------------------------------------------------------------------
# calibration constant + serve summary line
# ---------------------------------------------------------------------------


def test_link_validation_names_the_calibration_constant():
    cm = ChipMeshConfig(data=2, model=2, fabric=FB)
    sps = chain(cm)
    with obs.collecting():
        v = link_validation(sps, measured_collective_s=1e-3)
        assert v["link_clock_calibration"] == v["measured_over_modeled"]
        assert v["link_clock_calibration"] == pytest.approx(
            1e-3 / v["modeled_link_s"]
        )
        # raw seconds always reported next to the ratio, and as gauges
        assert v["modeled_link_s"] > 0
        assert v["measured_collective_s"] == 1e-3
        assert obs.get_value("fabric_modeled_link_seconds") == \
            v["modeled_link_s"]
        assert obs.get_value("fabric_link_clock_calibration") == \
            v["link_clock_calibration"]
    # without a measurement the ratio is None, raw modeled time still there
    v0 = link_validation(sps, None)
    assert v0["link_clock_calibration"] is None
    assert v0["modeled_link_s"] > 0


def test_serve_obs_summary_line(capsys):
    from repro.configs import ARCHS, reduced
    from repro.launch.serve import ServeSettings, serve_batch

    cfg = reduced(ARCHS["smollm-135m"], n_layers=1)
    rollup = {
        "totals": {
            "latency_s": 1e-3, "digitization_energy_pj": 1e6,
            "ema_energy_pj": 0.0, "ema_bits_per_pass": 128.0,
            "crosschip_bits_per_pass": 0, "model_resident": True,
        },
        "mesh": {"n_chips": 4},
        "exec_backend": "shard_map",
    }
    st = ServeSettings(batch=2, prompt_len=8, gen_len=4)
    with obs.collecting() as reg:
        serve_batch(cfg, st, fabric_rollup=rollup)
    line = [l for l in capsys.readouterr().out.splitlines()
            if l.startswith("[serve] obs")]
    assert len(line) == 1
    assert "fused" in line[0] and "link_clock_calibration" in line[0]
    assert reg.counter("serve_requests_total").value() == 2.0
    assert reg.histogram("serve_prefill_seconds").count() == 1
    assert reg.counter("fabric_ema_bits_total").value() > 0
    # metrics off -> the original batching line comes back
    serve_batch(cfg, st, fabric_rollup=rollup)
    out = capsys.readouterr().out
    assert "[serve] batch" in out and "[serve] obs" not in out
