"""Hypothesis property tests on the system's core invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as hst  # noqa: E402

from repro.core import adc
from repro.core import search_tree as st
from repro.core.cim_array import bit_planes, from_bit_planes
from repro.core.cim_linear import CiMConfig, cim_matmul, quantize_symmetric

_settings = settings(max_examples=25, deadline=None)


@given(
    pmf=hst.lists(hst.floats(0.001, 1.0), min_size=2, max_size=32),
)
@_settings
def test_any_pmf_yields_valid_optimal_tree(pmf):
    p = np.asarray(pmf) / np.sum(pmf)
    tree = st.optimal_tree(p)
    st.validate_tree(tree)
    e = tree.expected_depth(p)
    n = len(p)
    assert 1.0 - 1e-9 <= e <= np.ceil(np.log2(n)) + np.log2(n) + 1


@given(
    bits=hst.integers(2, 6),
    seed=hst.integers(0, 2**30),
)
@_settings
def test_conversion_error_bounded_by_one_lsb(bits, seed):
    """Ideal-comparator conversion never deviates from floor quantization."""
    v = jax.random.uniform(jax.random.PRNGKey(seed), (512,))
    cfg = adc.ADCConfig(bits=bits, mode="sar", n_ref_columns=max(32, 1 << bits))
    res = adc.convert(v, cfg)
    ideal = adc.quantize_ideal(v, bits)
    assert (res.codes == ideal).all()


@given(
    bits=hst.integers(2, 8),
    signed=hst.booleans(),
    seed=hst.integers(0, 2**30),
)
@_settings
def test_bit_plane_roundtrip(bits, signed, seed):
    lo = -(1 << (bits - 1)) if signed else 0
    hi = (1 << (bits - 1)) if signed else (1 << bits)
    x = jax.random.randint(jax.random.PRNGKey(seed), (64,), lo, hi)
    planes = bit_planes(x, bits, signed)
    back = from_bit_planes(planes, bits, signed)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


@given(
    m=hst.integers(1, 8),
    k_tiles=hst.integers(1, 4),
    n=hst.integers(1, 8),
    seed=hst.integers(0, 2**30),
)
@_settings
def test_cim_bitplane_exactness_property(m, k_tiles, n, seed):
    """For any shape, 16-row arrays + 5-bit ADC == exact integer matmul."""
    k = 16 * k_tiles
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (m, k))
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n))
    cfg = CiMConfig(mode="bitplane", a_bits=3, w_bits=3, adc_bits=5, rows=16, ste=False)
    y = cim_matmul(x, w, cfg)
    xi, sx = quantize_symmetric(x, 3, True)
    wi, sw = quantize_symmetric(w, 3, True, per_axis=-1)
    np.testing.assert_allclose(np.asarray(y), np.asarray((xi @ wi) * sx * sw), rtol=1e-4, atol=1e-5)


@given(
    bits=hst.integers(2, 8),
    signed=hst.booleans(),
    seed=hst.integers(0, 2**30),
)
@_settings
def test_quantize_symmetric_bounds(bits, signed, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (128,)) * 10
    xi, scale = quantize_symmetric(x, bits, signed)
    qmax = (1 << (bits - 1)) - 1 if signed else (1 << bits) - 1
    lo = -qmax - 1 if signed else 0
    assert float(xi.min()) >= lo and float(xi.max()) <= qmax
    if signed:
        # dequantized error bounded by scale/2 within representable range
        err = jnp.abs(xi * scale - jnp.clip(x, lo * scale, qmax * scale))
        assert float(err.max()) <= float(scale) * 0.5 + 1e-6


@given(seed=hst.integers(0, 2**30))
@_settings
def test_grad_compression_error_feedback_unbiased(seed):
    """Quantize with error feedback: accumulated estimate converges to mean."""
    from repro.optim.grad_compression import dequantize_int8, quantize_int8

    g = np.asarray(jax.random.normal(jax.random.PRNGKey(seed), (256,)))
    e = np.zeros_like(g)
    acc = np.zeros_like(g)
    steps = 50
    for _ in range(steps):
        q, s = quantize_int8(jnp.asarray(g + e))
        deq = np.asarray(dequantize_int8(q, s))
        e = (g + e) - deq
        acc += deq
    np.testing.assert_allclose(acc / steps, g, atol=np.abs(g).max() / 120)


@given(
    rows=hst.sampled_from([8, 16, 32]),
    p=hst.floats(0.05, 0.9),
)
@_settings
def test_mav_pmf_properties(rows, p):
    from repro.core.mav_stats import analytic_mav_pmf, code_pmf_from_mav

    pmf = analytic_mav_pmf(rows, p)
    assert pmf.shape == (rows + 1,)
    assert pmf.sum() == pytest.approx(1.0, abs=1e-9)
    cp = code_pmf_from_mav(pmf, rows, 5)
    assert cp.sum() == pytest.approx(1.0, abs=1e-9)
    # mean of code distribution tracks p
    mean_code = (np.arange(32) * cp).sum() / 31.0
    assert abs(mean_code - p) < 0.15
