"""Property tests on the system's core invariants.

Runs under Hypothesis when it is installed; otherwise a seeded pure-pytest
stand-in draws ``max_examples`` deterministic cases per test (crc32 of
``"<test name>:<case index>"`` seeds a numpy Generator), so the suite
exercises the same invariants — with reproducible failures — in
environments where Hypothesis cannot be added.
"""

import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as hst

    HYPOTHESIS = True
except ImportError:  # seeded fallback: same decorators, deterministic draws
    HYPOTHESIS = False

    class _Strategy:
        """A draw function ``numpy.random.Generator -> value``."""

        def __init__(self, draw):
            self.draw = draw

    class hst:  # noqa: N801 - stands in for hypothesis.strategies
        @staticmethod
        def floats(lo, hi):
            return _Strategy(lambda rng: float(rng.uniform(lo, hi)))

        @staticmethod
        def integers(lo, hi):
            return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

        @staticmethod
        def sampled_from(seq):
            items = list(seq)
            return _Strategy(lambda rng: items[int(rng.integers(len(items)))])

        @staticmethod
        def lists(elem, min_size=0, max_size=10):
            return _Strategy(
                lambda rng: [
                    elem.draw(rng)
                    for _ in range(int(rng.integers(min_size, max_size + 1)))
                ]
            )

    def settings(max_examples=25, deadline=None):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            def runner():
                for i in range(getattr(fn, "_max_examples", 25)):
                    seed = zlib.crc32(f"{fn.__name__}:{i}".encode())
                    rng = np.random.default_rng(seed)
                    kwargs = {k: s.draw(rng) for k, s in strategies.items()}
                    try:
                        fn(**kwargs)
                    except AssertionError as exc:
                        raise AssertionError(
                            f"falsifying example #{i} (seed {seed}): {kwargs}"
                        ) from exc

            # no functools.wraps: it would expose the strategy params via
            # __wrapped__ and pytest would demand them as fixtures
            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            return runner

        return deco


from repro.core import adc  # noqa: E402
from repro.core import search_tree as st  # noqa: E402
from repro.core.cim_array import bit_planes, from_bit_planes  # noqa: E402
from repro.core.cim_linear import CiMConfig, cim_matmul, quantize_symmetric  # noqa: E402
from repro.fabric.tiles import column_tile_matmul  # noqa: E402

_settings = settings(max_examples=25, deadline=None)


def test_property_suite_active():
    """The suite must run somewhere: either Hypothesis drives it or the
    seeded fallback does — never an importorskip."""
    sample = hst.integers(3, 3)
    if not HYPOTHESIS:
        assert sample.draw(np.random.default_rng(0)) == 3


@given(
    pmf=hst.lists(hst.floats(0.001, 1.0), min_size=2, max_size=32),
)
@_settings
def test_any_pmf_yields_valid_optimal_tree(pmf):
    p = np.asarray(pmf) / np.sum(pmf)
    tree = st.optimal_tree(p)
    st.validate_tree(tree)
    e = tree.expected_depth(p)
    n = len(p)
    assert 1.0 - 1e-9 <= e <= np.ceil(np.log2(n)) + np.log2(n) + 1


@given(
    bits=hst.integers(2, 6),
    seed=hst.integers(0, 2**30),
)
@_settings
def test_conversion_error_bounded_by_one_lsb(bits, seed):
    """Ideal-comparator conversion never deviates from floor quantization."""
    v = jax.random.uniform(jax.random.PRNGKey(seed), (512,))
    cfg = adc.ADCConfig(bits=bits, mode="sar", n_ref_columns=max(32, 1 << bits))
    res = adc.convert(v, cfg)
    ideal = adc.quantize_ideal(v, bits)
    assert (res.codes == ideal).all()


@given(
    bits=hst.integers(2, 8),
    signed=hst.booleans(),
    seed=hst.integers(0, 2**30),
)
@_settings
def test_bit_plane_roundtrip(bits, signed, seed):
    lo = -(1 << (bits - 1)) if signed else 0
    hi = (1 << (bits - 1)) if signed else (1 << bits)
    x = jax.random.randint(jax.random.PRNGKey(seed), (64,), lo, hi)
    planes = bit_planes(x, bits, signed)
    back = from_bit_planes(planes, bits, signed)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


@given(
    m=hst.integers(1, 8),
    k_tiles=hst.integers(1, 4),
    n=hst.integers(1, 8),
    seed=hst.integers(0, 2**30),
)
@_settings
def test_cim_bitplane_exactness_property(m, k_tiles, n, seed):
    """For any shape, 16-row arrays + 5-bit ADC == exact integer matmul."""
    k = 16 * k_tiles
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (m, k))
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n))
    cfg = CiMConfig(mode="bitplane", a_bits=3, w_bits=3, adc_bits=5, rows=16, ste=False)
    y = cim_matmul(x, w, cfg)
    xi, sx = quantize_symmetric(x, 3, True)
    wi, sw = quantize_symmetric(w, 3, True, per_axis=-1)
    np.testing.assert_allclose(np.asarray(y), np.asarray((xi @ wi) * sx * sw), rtol=1e-4, atol=1e-5)


@given(
    m=hst.integers(1, 6),
    k_tiles=hst.integers(1, 3),
    n=hst.integers(1, 12),
    cols=hst.integers(1, 16),
    seed=hst.integers(0, 2**30),
)
@_settings
def test_column_tile_matmul_tiling_invariance(m, k_tiles, n, cols, seed):
    """The output-column tile width is an execution detail: any ``cols``
    produces the bit-identical integer result and the same conversion /
    comparison census as the single full-width tile."""
    k = 16 * k_tiles
    key = jax.random.PRNGKey(seed)
    cim = CiMConfig(mode="bitplane", a_bits=4, w_bits=4, adc_bits=5, rows=16, ste=False)
    x_int, _ = quantize_symmetric(jax.random.normal(key, (m, k)), 4, True)
    w_int, _ = quantize_symmetric(
        jax.random.normal(jax.random.fold_in(key, 1), (k, n)), 4, True, per_axis=-1
    )
    y_full, st_full = column_tile_matmul(x_int, w_int, cim, cols=n)
    y_tiled, st_tiled = column_tile_matmul(x_int, w_int, cim, cols=cols)
    np.testing.assert_array_equal(np.asarray(y_tiled), np.asarray(y_full))
    assert int(st_tiled.conversions) == int(st_full.conversions)
    assert int(st_tiled.comparisons) == int(st_full.comparisons)
    # the tiled walk computes the exact integer product
    np.testing.assert_array_equal(np.asarray(y_tiled), np.asarray(x_int @ w_int))


@given(
    bits=hst.integers(2, 8),
    signed=hst.booleans(),
    seed=hst.integers(0, 2**30),
)
@_settings
def test_quantize_symmetric_bounds(bits, signed, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (128,)) * 10
    xi, scale = quantize_symmetric(x, bits, signed)
    qmax = (1 << (bits - 1)) - 1 if signed else (1 << bits) - 1
    lo = -qmax - 1 if signed else 0
    assert float(xi.min()) >= lo and float(xi.max()) <= qmax
    if signed:
        # dequantized error bounded by scale/2 within representable range
        err = jnp.abs(xi * scale - jnp.clip(x, lo * scale, qmax * scale))
        assert float(err.max()) <= float(scale) * 0.5 + 1e-6


@given(
    bits=hst.integers(2, 7),
    seed=hst.integers(0, 2**30),
)
@_settings
def test_requantization_qmax_monotonicity(bits, seed):
    """Re-quantization — the graph's block-boundary activation step — is
    lossless on grid points, and its worst-case error bound (one half LSB,
    ``absmax / (2 * qmax)``) strictly shrinks as qmax grows: the observed
    error at ``bits + 1`` always sits under the coarser grid's bound."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (256,)) * 4
    absmax = float(jnp.abs(x).max())

    def dequant_err(b):
        xi, scale = quantize_symmetric(x, b, True)
        q = (1 << (b - 1)) - 1
        clipped = jnp.clip(x, (-q - 1) * scale, q * scale)
        return xi * scale, float(jnp.abs(xi * scale - clipped).max()), float(scale)

    xq_lo, err_lo, scale_lo = dequant_err(bits)
    _, err_hi, scale_hi = dequant_err(bits + 1)
    # one extra bit roughly halves the LSB, so the finer grid's observed
    # error sits strictly under the coarser grid's half-LSB bound
    assert scale_hi < scale_lo
    assert err_hi <= 0.5 * scale_hi + 1e-6 < 0.5 * scale_lo + 1e-6
    assert err_lo <= 0.5 * scale_lo + 1e-6
    if absmax > 0:
        # re-quantizing an already-quantized signal at the same width is
        # exact: grid points survive the round trip bit-for-bit
        xi2, s2 = quantize_symmetric(xq_lo, bits, True)
        np.testing.assert_array_equal(np.asarray(xi2 * s2), np.asarray(xq_lo))


@given(seed=hst.integers(0, 2**30))
@_settings
def test_grad_compression_error_feedback_unbiased(seed):
    """Quantize with error feedback: accumulated estimate converges to mean."""
    from repro.optim.grad_compression import dequantize_int8, quantize_int8

    g = np.asarray(jax.random.normal(jax.random.PRNGKey(seed), (256,)))
    e = np.zeros_like(g)
    acc = np.zeros_like(g)
    steps = 50
    for _ in range(steps):
        q, s = quantize_int8(jnp.asarray(g + e))
        deq = np.asarray(dequantize_int8(q, s))
        e = (g + e) - deq
        acc += deq
    np.testing.assert_allclose(acc / steps, g, atol=np.abs(g).max() / 120)


@given(
    rows=hst.sampled_from([8, 16, 32]),
    p=hst.floats(0.05, 0.9),
)
@_settings
def test_mav_pmf_properties(rows, p):
    from repro.core.mav_stats import analytic_mav_pmf, code_pmf_from_mav

    pmf = analytic_mav_pmf(rows, p)
    assert pmf.shape == (rows + 1,)
    assert pmf.sum() == pytest.approx(1.0, abs=1e-9)
    cp = code_pmf_from_mav(pmf, rows, 5)
    assert cp.sum() == pytest.approx(1.0, abs=1e-9)
    # mean of code distribution tracks p
    mean_code = (np.arange(32) * cp).sum() / 31.0
    assert abs(mean_code - p) < 0.15
