"""Analytic area/energy/latency models: Table I anchors + Fig. 7a,b trends."""

import pytest

from repro.core.energy_area import (
    ADC_STYLES,
    area_um2,
    design_space,
    energy_pj,
    latency_cycles,
    table1,
)


def test_table1_anchors_exact():
    t = table1()
    assert t["sar"]["area_um2"] == pytest.approx(5235.20)
    assert t["sar"]["energy_pj"] == pytest.approx(105.0)
    assert t["flash"]["area_um2"] == pytest.approx(10703.36)
    assert t["flash"]["energy_pj"] == pytest.approx(952.0)
    assert t["in_memory"]["area_um2"] == pytest.approx(207.8)
    assert t["in_memory"]["energy_pj"] == pytest.approx(74.23)


def test_paper_headline_ratios():
    """~25x less area than SAR, ~51x than Flash; ~1.4x / ~13x energy."""
    assert 24 < area_um2("sar", 5) / area_um2("in_memory", 5) < 27
    assert 49 < area_um2("flash", 5) / area_um2("in_memory", 5) < 53
    assert 1.3 < energy_pj("sar", 5) / energy_pj("in_memory", 5) < 1.5
    assert 12 < energy_pj("flash", 5) / energy_pj("in_memory", 5) < 14


def test_flash_area_exponential_in_bits():
    a = [area_um2("flash", b) for b in range(3, 9)]
    ratios = [a[i + 1] / a[i] for i in range(len(a) - 1)]
    assert all(1.8 < r < 2.3 for r in ratios)  # ~2x per bit


def test_in_memory_area_flat_in_bits():
    a3, a8 = area_um2("in_memory", 3), area_um2("in_memory", 8)
    assert a8 / a3 < 1.3  # nearly flat (Fig. 7a)


def test_latency_orderings():
    """Fig. 7b: flash 1 cycle; SAR linear in bits; hybrid in between."""
    for b in (4, 5, 6):
        assert latency_cycles("flash", b) == 1
        assert latency_cycles("sar", b) == b
        assert 1 < latency_cycles("in_memory_hybrid", b) < b
        assert latency_cycles("in_memory_asym", b) < latency_cycles("in_memory", b)


def test_asym_energy_saving_proportional():
    """Fig. 4c: 3.7/5 comparisons => ~26% energy saving."""
    e_sym = energy_pj("in_memory", 5)
    e_asym = energy_pj("in_memory_asym", 5)
    assert 0.70 < e_asym / e_sym < 0.80


def test_hybrid_saves_reference_energy():
    e_plain = energy_pj("in_memory", 5)
    e_hybrid = energy_pj("in_memory_hybrid", 5, flash_share=3)
    assert e_hybrid < e_plain * 1.05  # shared flash refs amortize


def test_voltage_scaling_quadratic():
    e1 = energy_pj("in_memory", 5, vdd=1.0)
    e2 = energy_pj("in_memory", 5, vdd=0.8)
    assert e2 / e1 == pytest.approx(0.64, rel=1e-6)


def test_design_space_complete():
    ds = design_space()
    for style in ADC_STYLES:
        assert len(ds[style]["area_um2"]) == len(list(range(3, 9)))
