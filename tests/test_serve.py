"""Serving: batched prefill+decode, sliding-window ring cache, CiM mode."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.core.cim_linear import CiMConfig
from repro.launch.serve import ServeSettings, parse_fabric_mesh, serve_batch
from repro.models import build_model
from repro.models import layers as L


def test_parse_fabric_mesh():
    """--fabric-mesh DxM: any mesh make_chip_mesh accepts, loud errors else."""
    assert parse_fabric_mesh("2x4") == (2, 4)
    assert parse_fabric_mesh("1x1") == (1, 1)
    assert parse_fabric_mesh("4X2") == (4, 2)  # case-insensitive
    for bad in ("2x", "axb", "2x2x2", ""):
        with pytest.raises(ValueError, match="fabric-mesh"):
            parse_fabric_mesh(bad)
    with pytest.raises(ValueError, match="axes must be >= 1"):
        parse_fabric_mesh("0x2")


def test_serve_batch_runs():
    cfg = reduced(ARCHS["smollm-135m"], n_layers=2)
    out = serve_batch(cfg, ServeSettings(batch=3, prompt_len=16, gen_len=8))
    assert out["generated"].shape == (3, 8)
    assert out["decode_tok_s"] > 0


def test_serve_with_cim_quantization():
    """The paper's technique as a serving feature (fake_quant inference)."""
    cfg = dataclasses.replace(
        reduced(ARCHS["smollm-135m"], n_layers=2),
        cim=CiMConfig(mode="fake_quant", adc_bits=8, rows=64, ste=False),
    )
    out = serve_batch(cfg, ServeSettings(batch=2, prompt_len=8, gen_len=4))
    assert out["generated"].shape == (2, 4)


def test_window_ring_cache_equals_full_cache_within_window():
    """Windowed decode == full-cache decode when context fits the window."""
    base = reduced(ARCHS["smollm-135m"], n_layers=2)
    b, s = 2, 48
    x = jax.random.randint(jax.random.PRNGKey(0), (b, s), 0, base.vocab)

    cfg_full = base
    cfg_win = dataclasses.replace(base, sliding_window=64)  # window > context
    logits = {}
    for tag, cfg in (("full", cfg_full), ("win", cfg_win)):
        m = build_model(cfg)
        p = m.init(jax.random.PRNGKey(1))
        cache = m.make_cache(b, 64)
        _, cache = m.prefill(p, x[:, :-1], cache)
        ld, _ = m.decode_step(p, x[:, -1], jnp.asarray(s - 1), cache)
        logits[tag] = ld
    np.testing.assert_allclose(
        np.asarray(logits["full"]), np.asarray(logits["win"]), atol=2e-4
    )


def test_decode_beyond_window_truncates_attention():
    """With a small window, early tokens stop influencing decode logits."""
    cfg = dataclasses.replace(
        reduced(ARCHS["smollm-135m"], n_layers=2), sliding_window=16
    )
    m = build_model(cfg)
    p = m.init(jax.random.PRNGKey(1))
    b, s = 1, 48
    x1 = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, cfg.vocab)
    x2 = x1.at[:, :8].set((x1[:, :8] + 7) % cfg.vocab)  # differ only outside window
    outs = []
    for x in (x1, x2):
        cache = m.make_cache(b, s)
        _, cache = m.prefill(p, x[:, :-1], cache)
        ld, _ = m.decode_step(p, x[:, -1], jnp.asarray(s - 1), cache)
        outs.append(np.asarray(ld))
    np.testing.assert_allclose(outs[0], outs[1], atol=2e-4)
