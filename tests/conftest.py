import os

# Keep smoke tests on the CPU platform (the dry-run sets its own 512-device
# flag in repro.launch.dryrun, which must be the FIRST import there — never
# set globally here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Force 8 host devices so the shard_map execution backend of
# repro.fabric.shard runs on a REAL multi-device mesh in the tier-1 suite
# (tests/test_fabric_shard.py). Must land before the first jax import; an
# explicit caller-provided flag wins.
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=8"
    ).strip()
