import os

# Keep smoke tests on the single real CPU device (the dry-run sets its own
# 512-device flag in repro.launch.dryrun, which must be the FIRST import
# there — never set globally here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
