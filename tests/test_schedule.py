"""Collaborative digitization schedules (paper Figs. 2, 3, 5c)."""

from repro.core.schedule import hybrid_schedule, pair_sar_schedule, throughput_summary


def test_pair_sar_timeline():
    s = pair_sar_schedule(bits=5, n_conversions=4)
    assert s.n_conversions == 4
    assert s.n_arrays == 2
    # each conversion: 1 compute + 5 ref/compare cycles
    assert s.n_cycles == 4 * (1 + 5)
    # both arrays alternate roles: each computes twice
    computes = [sl for sl in s.slots if sl.role == "compute"]
    assert {sl.array for sl in computes} == {"A", "B"}


def test_hybrid_timeline_matches_fig3():
    s = hybrid_schedule(bits=5, flash_bits=2, n_cim_arrays=3)
    assert s.n_conversions == 3
    assert s.n_arrays == 3 + 3  # 3 CiM + 3 reference arrays
    # hybrid: parallel compute + staggered flash + parallel SAR tails
    assert s.n_cycles <= 1 + 3 + (5 - 2) + 1


def test_throughput_summary_gain():
    t = throughput_summary()
    # the paper's system-level claim: saved ADC area funds >10x more
    # conversions per unit area even at interleaved (half) duty cycle
    assert t["dedicated_adc_area_ratio"] > 24
    assert t["conversions_per_area_gain"] > 10
