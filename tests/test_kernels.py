"""Pallas kernels vs jnp oracles: shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.cim_matmul import adc_quant_pallas, cim_matmul_pallas
from repro.kernels.ops import adc_quant_op, cim_matmul_op


def _ints(shape, lo, hi, seed, dtype=jnp.float32):
    x = jax.random.randint(jax.random.PRNGKey(seed), shape, lo, hi)
    return x.astype(dtype)


@pytest.mark.parametrize(
    "m,k,n,rows,block_k",
    [
        (128, 512, 128, 128, 512),
        (128, 512, 128, 64, 256),
        (256, 1024, 128, 128, 512),
        (128, 256, 256, 16, 128),
    ],
)
def test_fakequant_kernel_vs_ref(m, k, n, rows, block_k):
    xi = _ints((m, k), -50, 50, 0)
    wi = _ints((k, n), -50, 50, 1)
    y_k = cim_matmul_pallas(
        xi, wi, rows=rows, adc_bits=8, mode="fake_quant",
        block_k=block_k, interpret=True,
    )
    y_r = ref.cim_matmul_ref(xi, wi, rows=rows, adc_bits=8, mode="fake_quant")
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), rtol=1e-6)


@pytest.mark.parametrize("a_bits,w_bits,rows,adc_bits", [(4, 4, 128, 8), (3, 5, 64, 7), (4, 4, 16, 5)])
def test_bitplane_kernel_vs_ref(a_bits, w_bits, rows, adc_bits):
    m, k, n = 128, 512, 128
    lo_a, hi_a = -(1 << (a_bits - 1)), (1 << (a_bits - 1))
    lo_w, hi_w = -(1 << (w_bits - 1)), (1 << (w_bits - 1))
    xi = _ints((m, k), lo_a, hi_a, 2, jnp.int32)
    wi = _ints((k, n), lo_w, hi_w, 3, jnp.int32)
    y_k = cim_matmul_pallas(
        xi, wi, rows=rows, adc_bits=adc_bits, mode="bitplane",
        a_bits=a_bits, w_bits=w_bits, interpret=True,
    )
    y_r = ref.cim_matmul_ref(
        xi.astype(jnp.float32), wi.astype(jnp.float32),
        rows=rows, adc_bits=adc_bits, mode="bitplane",
        a_bits=a_bits, w_bits=w_bits,
    )
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), rtol=1e-6)


def test_bitplane_kernel_exact_on_chip_geometry():
    """rows=16 + 5-bit ADC: kernel output == plain integer matmul."""
    xi = _ints((128, 512), -8, 8, 4, jnp.int32)
    wi = _ints((512, 128), -8, 8, 5, jnp.int32)
    y = cim_matmul_pallas(
        xi, wi, rows=16, adc_bits=5, mode="bitplane", a_bits=4, w_bits=4,
        block_k=512, interpret=True,
    )
    want = xi.astype(jnp.float32) @ wi.astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("shape", [(7, 130), (100, 100), (256, 512), (1, 31)])
@pytest.mark.parametrize("bits", [3, 5, 8])
def test_adc_quant_kernel_sweep(shape, bits):
    v = jax.random.uniform(jax.random.PRNGKey(6), shape)
    got = adc_quant_op(v, bits=bits)
    want = ref.adc_quant_ref(v, bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-7)


@pytest.mark.parametrize(
    "batch_shape,k,n", [((3, 70), 300, 50), ((5,), 64, 8), ((2, 3, 4), 128, 16)]
)
def test_wrapper_odd_shapes(batch_shape, k, n):
    x = jax.random.normal(jax.random.PRNGKey(7), (*batch_shape, k))
    w = jax.random.normal(jax.random.PRNGKey(8), (k, n))
    y = cim_matmul_op(x, w, rows=64, adc_bits=10)
    assert y.shape == (*batch_shape, n)
    rel = float(jnp.abs(y - x @ w).max() / jnp.abs(x @ w).max())
    assert rel < 0.15  # 10-bit ADC: small composite quantization error


def test_wrapper_matches_core_fakequant_semantics():
    """ops.cim_matmul_op == core.cim_linear fake_quant (ideal ADC)."""
    from repro.core.cim_linear import CiMConfig, cim_matmul

    x = jax.random.normal(jax.random.PRNGKey(9), (32, 192))
    w = jax.random.normal(jax.random.PRNGKey(10), (192, 24))
    y_kernel = cim_matmul_op(x, w, rows=64, adc_bits=6, block_m=128, block_n=128, block_k=64)
    y_core = cim_matmul(
        x, w, CiMConfig(mode="fake_quant", adc_bits=6, rows=64, ste=False)
    )
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_core), rtol=1e-5, atol=1e-5)
