"""Whole-model fused shard_map forward (``repro.fabric.program``): chain
extraction, eligibility, 1x1 bit-exactness vs the per-layer
``execute_sharded_matmul`` loop (noisy ADC included), multi-chip agreement,
the at-most-one-all-gather collective census, and the measured-vs-modeled
link-latency validation. ``tests/conftest.py`` forces 8 host devices."""

import jax
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core.cim_linear import CiMConfig
from repro.fabric import (
    ChipMeshConfig,
    FabricConfig,
    compile_forward,
    link_validation,
    map_matmul,
    measure_forward,
    model_forward_chain,
    per_layer_forward,
    program_eligibility,
    render_markdown,
    shard_model,
    shard_placement,
    sharded_fabric_report,
)

FB = FabricConfig(mode="pair_sar", rows=16, cols=32, n_arrays=8)
CIM_BP = CiMConfig(mode="bitplane", a_bits=4, w_bits=4, adc_bits=5, rows=16, ste=False)
NOISY = CiMConfig(
    mode="bitplane", a_bits=4, w_bits=4, adc_bits=5, rows=16, ste=False,
    comparator_sigma=0.05,
)
SHAPES = [("l0", 4, 64, 64), ("l1", 4, 64, 96), ("l2", 4, 96, 32)]


def chain(cm, cim=CIM_BP, shapes=SHAPES):
    return [
        shard_placement(map_matmul(name, m, k, n, cm.fabric, cim=cim), cm)
        for name, m, k, n in shapes
    ]


# ---------------------------------------------------------------------------
# forward-chain extraction
# ---------------------------------------------------------------------------


def test_model_forward_chain_dense_block():
    cfg = get_config("smollm-135m")
    names = [n for n, *_ in model_forward_chain(cfg, 4, block_only=True)]
    assert names == ["block.q_proj", "block.o_proj", "block.gate_proj", "block.down_proj"]
    # consecutive layers chain dimensionally: N_i == K_{i+1}
    shapes = model_forward_chain(cfg, 4, block_only=True)
    for (_, _, _, n_prev), (_, _, k_next, _) in zip(shapes, shapes[1:]):
        assert n_prev == k_next


def test_model_forward_chain_moe_takes_one_expert():
    """A token's critical path runs through ONE activated expert — the chain
    must not string the top_k parallel experts in series."""
    cfg = get_config("qwen3-moe-30b-a3b")
    names = [n for n, *_ in model_forward_chain(cfg, 2, block_only=True)]
    assert names == [
        "block.q_proj", "block.o_proj",
        "block.expert0.gate_proj", "block.expert0.down_proj",
    ]
    shapes = model_forward_chain(cfg, 2, block_only=True)
    for (_, _, _, n_prev), (_, _, k_next, _) in zip(shapes, shapes[1:]):
        assert n_prev == k_next


def test_model_forward_chain_full_model_ends_at_unembed():
    cfg = get_config("smollm-135m")
    shapes = model_forward_chain(cfg, 2)
    assert shapes[-1][0] == "unembed"
    assert len(shapes) == 4 * cfg.n_layers + 1
    for (_, _, _, n_prev), (_, _, k_next, _) in zip(shapes, shapes[1:]):
        assert n_prev == k_next


# ---------------------------------------------------------------------------
# eligibility + compile-time errors
# ---------------------------------------------------------------------------


def test_program_eligibility_clean_chain():
    cm = ChipMeshConfig(data=2, model=2, fabric=FB)
    assert program_eligibility(chain(cm), cm) == []
    assert program_eligibility([], cm) == ["empty layer chain"]


def test_program_eligibility_reports_chain_break_and_ragged_k():
    cm = ChipMeshConfig(data=2, model=2, fabric=FB)
    broken = chain(cm, shapes=[("a", 4, 64, 64), ("b", 4, 96, 64)])
    assert any("chain break" in p for p in program_eligibility(broken, cm))
    # K=96 has 6 tiles (divides model=2) but 96 % (2*16) == 0, so make a
    # genuinely tile-ragged K: 3 tiles on model=2 records a fallback
    cmf = ChipMeshConfig(model=2, fabric=FB)
    ragged = [shard_placement(map_matmul("r", 4, 40, 64, FB, cim=CIM_BP), cmf)]
    probs = program_eligibility(ragged, cmf)
    assert any("replication fallbacks" in p for p in probs)
    # 16 chips > 8 forced devices
    big = ChipMeshConfig(data=4, model=4, fabric=FB)
    sp_big = [shard_placement(map_matmul("l", 16, 256, 64, FB, cim=CIM_BP), big)]
    assert any("jax device" in p for p in program_eligibility(sp_big, big))


def test_compile_forward_backend_resolution_and_errors():
    cm = ChipMeshConfig(data=2, model=2, fabric=FB)
    assert compile_forward(chain(cm), cm, CIM_BP).backend == "shard_map"
    assert compile_forward(chain(cm), cm, CIM_BP, backend="sequential").backend == "sequential"
    big = ChipMeshConfig(data=4, model=4, fabric=FB)
    sp_big = [shard_placement(map_matmul("l", 16, 256, 64, FB, cim=CIM_BP), big)]
    # auto falls back with the reasons kept; explicit shard_map raises them
    prog = compile_forward(sp_big, big, CIM_BP)
    assert prog.backend == "sequential" and prog.problems
    with pytest.raises(ValueError, match="fused shard_map program unavailable"):
        compile_forward(sp_big, big, CIM_BP, backend="shard_map")
    with pytest.raises(ValueError, match="ste=False"):
        compile_forward(chain(cm), cm, CiMConfig(mode="bitplane", rows=16, ste=True))
    with pytest.raises(ValueError):
        compile_forward(chain(cm), cm, CiMConfig(mode="exact", ste=False))


def test_program_call_validates_shapes():
    cm = ChipMeshConfig(fabric=FB)
    prog = compile_forward(chain(cm), cm, CIM_BP)
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64))
    ws = prog.random_weights(jax.random.PRNGKey(1))
    with pytest.raises(ValueError, match="weight matrices"):
        prog(x, ws[:-1])
    with pytest.raises(ValueError, match="expects weights"):
        prog(x, list(reversed(ws)))
    bad_x = jax.random.normal(jax.random.PRNGKey(0), (4, 32))
    with pytest.raises(ValueError, match="input features"):
        prog(bad_x, ws)


# ---------------------------------------------------------------------------
# numerics: 1x1 bit-exact, multi-chip agreement
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cim,with_key", [(CIM_BP, False), (NOISY, True)])
def test_fused_1x1_bit_exact_vs_per_layer_loop(cim, with_key):
    """Acceptance: the fused program on a 1x1 mesh is bit-for-bit the loop
    of execute_sharded_matmul calls — noisy ADC included (per-layer
    fold_in(key, i) keys shared by both paths)."""
    cm = ChipMeshConfig(fabric=FB)
    prog = compile_forward(chain(cm, cim), cm, cim)
    assert prog.backend == "shard_map"  # auto fuses even on one chip
    key = jax.random.PRNGKey(7) if with_key else None
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64))
    ws = prog.random_weights(jax.random.PRNGKey(1))
    y = prog(x, ws, key=key)
    y_ref = per_layer_forward(x, ws, prog.placements, cm, cim, key=key,
                              backend="sequential")
    assert (np.asarray(y) == np.asarray(y_ref)).all()


@pytest.mark.parametrize("data,model", [(1, 2), (2, 1), (2, 2)])
def test_fused_multichip_matches_sequential_loop(data, model):
    """Acceptance: on a forced-device mesh the fused program matches the
    sequential per-layer loop to float tolerance (the integer partial sums
    make the reduce-scatter combine exact, so in practice it is equal)."""
    cm = ChipMeshConfig(data=data, model=model, fabric=FB)
    prog = compile_forward(chain(cm), cm, CIM_BP)
    assert prog.backend == "shard_map"
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64))
    ws = prog.random_weights(jax.random.PRNGKey(1))
    y, st = prog(x, ws, return_stats=True)
    y_ref, st_ref = per_layer_forward(
        x, ws, prog.placements, cm, CIM_BP, backend="sequential", return_stats=True
    )
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-5, rtol=1e-6)
    assert int(st.conversions) == int(st_ref.conversions)
    assert int(st.comparisons) == int(st_ref.comparisons)
    # noisy ADC: identical per-layer/chip/tile key derivation on both paths
    progn = compile_forward(chain(cm, NOISY), cm, NOISY)
    nk = jax.random.PRNGKey(9)
    y_n = progn(x, ws, key=nk)
    y_n_ref = per_layer_forward(x, ws, progn.placements, cm, NOISY, key=nk,
                                backend="sequential")
    np.testing.assert_allclose(np.asarray(y_n), np.asarray(y_n_ref), atol=1e-4, rtol=1e-5)


def test_fused_fake_quant_matches_loop():
    cim = CiMConfig(mode="fake_quant", a_bits=8, w_bits=8, adc_bits=5, rows=16, ste=False)
    cm = ChipMeshConfig(data=2, model=2, fabric=FB)
    prog = compile_forward(chain(cm, cim), cm, cim)
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 64))
    ws = prog.random_weights(jax.random.PRNGKey(3))
    y = prog(x, ws)
    y_ref = per_layer_forward(x, ws, prog.placements, cm, cim, backend="sequential")
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-5, rtol=1e-6)


def test_fused_batched_leading_dims_and_ragged_batch():
    cm = ChipMeshConfig(data=2, model=2, fabric=FB)
    sps = chain(cm, shapes=[("l0", 8, 64, 64)])
    prog = compile_forward(sps, cm, CIM_BP)
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 4, 64))  # flattens to 8 rows
    ws = prog.random_weights(jax.random.PRNGKey(1))
    y = prog(x, ws)
    assert y.shape == (2, 4, 64)
    y_ref = per_layer_forward(x, ws, sps, cm, CIM_BP, backend="sequential")
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-5, rtol=1e-6)
    # a runtime batch not divisible by the data axis falls back (auto) and
    # matches the sequential loop exactly
    x5 = jax.random.normal(jax.random.PRNGKey(4), (5, 64))
    y5 = prog(x5, ws)
    y5_ref = per_layer_forward(x5, ws, sps, cm, CIM_BP, backend="sequential")
    assert (np.asarray(y5) == np.asarray(y5_ref)).all()
    strict = compile_forward(sps, cm, CIM_BP, backend="shard_map")
    with pytest.raises(ValueError, match="not divisible by the data axis"):
        strict(x5, ws)


# ---------------------------------------------------------------------------
# collectives: one all-gather for the WHOLE forward
# ---------------------------------------------------------------------------


def test_fused_forward_has_at_most_one_all_gather():
    """Acceptance: counting collectives in the fused program's jaxpr — one
    reduce_scatter per inter-layer combine, ONE all_gather total (the final
    redistribution), no per-layer gather/re-scatter."""
    cm = ChipMeshConfig(data=2, model=2, fabric=FB)
    prog = compile_forward(chain(cm), cm, CIM_BP)
    counts = prog.collective_counts()
    assert counts["all_gather"] == 1
    assert counts["reduce_scatter"] == len(SHAPES)
    assert counts["all_to_all"] == 0 and counts["ppermute"] == 0
    # a single-chip mesh needs no gather at all
    cm1 = ChipMeshConfig(fabric=FB)
    prog1 = compile_forward(chain(cm1), cm1, CIM_BP)
    counts1 = prog1.collective_counts()
    assert counts1["all_gather"] == 0 and counts1["reduce_scatter"] == 0


def test_chain_program_reference_forward_and_example_input():
    """The program-agnostic measure_forward API (PR 5): the chain program
    exposes example_input/reference_forward like the graph program does."""
    cm = ChipMeshConfig(data=2, model=2, fabric=FB)
    prog = compile_forward(chain(cm), cm, CIM_BP)
    x = prog.example_input(jax.random.PRNGKey(0))
    assert x.shape == (prog.m, prog.placements[0].k)
    ws = prog.random_weights(jax.random.PRNGKey(1))
    y = prog(x, ws)
    y_ref = prog.reference_forward(x, ws, backend="sequential")
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-5, rtol=1e-6)


# ---------------------------------------------------------------------------
# measured-vs-modeled link latency
# ---------------------------------------------------------------------------


def test_measure_forward_and_link_validation():
    cm = ChipMeshConfig(data=2, model=2, fabric=FB)
    prog = compile_forward(chain(cm), cm, CIM_BP)
    meas = measure_forward(prog, iters=1, per_layer_backend="sequential")
    assert meas["backend"] == "shard_map" and meas["n_chips"] == 4
    assert meas["fused_s"] > 0 and meas["local_s"] > 0 and meas["per_layer_s"] > 0
    assert meas["measured_collective_s"] >= 0.0
    assert meas["modeled_link_s"] > 0  # model axis > 1 -> links carry bits
    assert meas["measured_over_modeled"] is not None
    # link_validation handles the no-links / unmeasured cases
    v = link_validation(prog.placements, None)
    assert v["measured_over_modeled"] is None
    cm1 = ChipMeshConfig(fabric=FB)
    v1 = link_validation(chain(cm1), 1e-3)
    assert v1["modeled_link_s"] == 0.0 and v1["measured_over_modeled"] is None


def test_report_renders_program_validation():
    cfg = get_config("smollm-135m")
    cm = ChipMeshConfig(data=2, model=2, fabric=FabricConfig(mode="hybrid", n_arrays=252))
    sps = shard_model(cfg, cm, tokens=4, block_only=True)
    measured = {
        "backend": "shard_map", "n_layers": 4, "fused_s": 1e-3,
        "per_layer_s": 5e-3, "fused_speedup_vs_per_layer": 5.0,
        "measured_collective_s": 2e-4, "modeled_link_s": 1e-6,
        "measured_over_modeled": 200.0,
    }
    rep = sharded_fabric_report(sps, cm, measured=measured)
    assert rep["program_validation"]["measured_over_modeled"] == 200.0
    md = render_markdown(rep)
    assert "fused program" in md and "calibration ratio" in md
    # reports without a measured section render unchanged
    assert "fused program" not in render_markdown(sharded_fabric_report(sps, cm))
