"""Sharding rules + loop-aware HLO analyzer."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.launch import shardings as sh
from repro.roofline.hlo_stats import analyze


@pytest.fixture(scope="module")
def mesh():
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))


def test_spec_divisible(mesh):
    with sh.record_fallbacks() as fb:
        spec = sh.spec_for(mesh, (16, 32), ("dp", "tp"), "t")
    assert spec == P("data", "model")
    assert not fb


def test_spec_fallback_records(mesh):
    # a 2-way model axis with an odd dim must record the replication fallback
    dev2 = np.array(jax.devices()[:2]).reshape(1, 2)
    mesh2 = Mesh(dev2, ("data", "model"))
    with sh.record_fallbacks() as fb:
        assert sh.spec_for(mesh2, (7,), ("tp",), "odd") == P(None)
    assert len(fb) == 1 and "odd" in fb[0]
    with sh.record_fallbacks() as fb2:
        assert sh.spec_for(mesh, (16,), ("tp",), "x") == P("model")
    assert not fb2


def test_fallback_recording_is_scoped():
    """Records don't leak across scopes (the old module-global bug) and
    nested recorders both observe inner fallbacks."""
    dev2 = np.array(jax.devices()[:2]).reshape(1, 2)
    mesh2 = Mesh(dev2, ("data", "model"))
    # outside any recorder: nothing to leak into, and no error
    sh.spec_for(mesh2, (7,), ("tp",), "unscoped")
    with sh.record_fallbacks() as outer:
        sh.spec_for(mesh2, (5,), ("tp",), "outer-only")
        with sh.record_fallbacks() as inner:
            sh.spec_for(mesh2, (3,), ("tp",), "both")
        sh.spec_for(mesh2, (9,), ("tp",), "outer-again")
    assert [m.split(":")[0] for m in inner] == ["both"]
    assert [m.split(":")[0] for m in outer] == ["outer-only", "both", "outer-again"]
    # a fresh recorder starts empty — nothing leaked from the calls above
    with sh.record_fallbacks() as fresh:
        pass
    assert fresh == []


def test_param_rules_cover_all_archs(mesh):
    from repro.configs import ARCHS, reduced
    from repro.models import build_model

    for name in sorted(ARCHS):
        cfg = reduced(ARCHS[name])
        model = build_model(cfg)
        sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        shd = sh.param_shardings(mesh, sds, cfg)
        assert len(jax.tree.leaves(shd, is_leaf=lambda x: hasattr(x, "spec"))) == len(
            jax.tree.leaves(sds)
        )


def test_hlo_analyzer_scan_trip_counts():
    def f(w, x):
        def body(c, wl):
            return c @ wl, None
        out, _ = lax.scan(body, x, w)
        return out.sum()

    for L in (3, 9):
        w = jnp.ones((L, 64, 64))
        x = jnp.ones((4, 64))
        hlo = jax.jit(f).lower(w, x).compile().as_text()
        st = analyze(hlo, 1)
        assert st.dot_flops == pytest.approx(2 * 4 * 64 * 64 * L, rel=1e-6)


def test_hlo_analyzer_counts_collectives():
    from repro.roofline.hlo_stats import HloStats

    fake_hlo = """ENTRY %main (p: f32[16]) -> f32[16] {
  %p = f32[16]{0} parameter(0)
  ROOT %ar = f32[16]{0} all-reduce(%p), replica_groups={{0,1,2,3}}, to_apply=%add
}
"""
    st = analyze(fake_hlo, 4)
    # all-reduce: 2*(4-1)/4 * 64 bytes = 96
    assert st.collective_total == pytest.approx(96.0)


def test_cache_shardings_seq_parallel_fallback(mesh):
    """kv heads not divisible -> sequence dim takes the tp axis."""
    from repro.configs import ARCHS
    cfg = ARCHS["command-r-plus-104b"]
    cache_sds = {
        "k": jax.ShapeDtypeStruct((2, 4, 64, 8, 16), jnp.bfloat16),
        "pos": jax.ShapeDtypeStruct((64,), jnp.int32),
    }
    # single-device mesh: everything divides; just check it runs
    shd = sh.cache_shardings(mesh, cache_sds, cfg)
    assert hasattr(shd["k"], "spec")
