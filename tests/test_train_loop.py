"""Train loop: loss descends, checkpoint-resume determinism, fault recovery."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.launch.train import TrainSettings, train
from repro.models import layers as Lmod


@pytest.fixture(autouse=True)
def _no_act_rules():
    Lmod.set_act_rules(None)
    yield
    Lmod.set_act_rules(None)


def _cfg():
    return reduced(ARCHS["smollm-135m"], n_layers=2, d_model=32, vocab=64,
                   n_heads=2, n_kv_heads=1, d_ff=64, head_dim=16)


def test_loss_decreases(tmp_path):
    st = TrainSettings(steps=40, batch=8, seq=64, lr=2e-3, warmup=5,
                       ckpt_dir=str(tmp_path), ckpt_every=100, log_every=100)
    out = train(_cfg(), st)
    assert out["final_loss"] < out["first_loss"] - 0.1


def test_resume_continues_identically(tmp_path):
    """Interrupted training + resume == uninterrupted run (seekable data +
    atomic checkpoints)."""
    cfg = _cfg()
    base = dict(batch=4, seq=32, lr=1e-3, warmup=2, log_every=100)
    # uninterrupted 20 steps
    st_a = TrainSettings(steps=20, ckpt_dir=str(tmp_path / "a"), ckpt_every=1000, **base)
    out_a = train(cfg, st_a)
    # interrupted at 10 (same 20-step LR schedule), then resumed
    st_b = TrainSettings(steps=20, ckpt_dir=str(tmp_path / "b"), ckpt_every=10, **base)
    train(cfg, st_b, stop_at=10)
    out_b = train(cfg, st_b)
    assert out_b["final_loss"] == pytest.approx(out_a["final_loss"], rel=1e-3)


def test_microbatch_accumulation_matches_full_batch(tmp_path):
    cfg = _cfg()
    base = dict(steps=5, batch=8, seq=32, lr=1e-3, warmup=1, log_every=100,
                ckpt_every=1000)
    out_full = train(cfg, TrainSettings(ckpt_dir=str(tmp_path / "f"), microbatches=1, **base))
    out_acc = train(cfg, TrainSettings(ckpt_dir=str(tmp_path / "m"), microbatches=2, **base))
    assert out_acc["final_loss"] == pytest.approx(out_full["final_loss"], rel=5e-2)


def test_run_with_restart_recovers():
    from repro.ft.watchdog import run_with_restart

    calls = {"n": 0}

    def flaky(resume):
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("simulated node failure")
        return 42

    assert run_with_restart(flaky, max_restarts=3) == 42
    assert calls["n"] == 3


def test_watchdog_flags_stragglers(tmp_path):
    import time
    from repro.ft.watchdog import Watchdog

    wd = Watchdog(tmp_path / "hb.json", straggler_factor=3.0, ema_alpha=0.5)
    wd.step(0)
    for s in range(1, 4):
        time.sleep(0.01)
        wd.step(s)
    time.sleep(0.2)  # 20x the EMA -> straggler
    out = wd.step(4)
    assert out["straggler"]
    assert (tmp_path / "hb.json").exists()
