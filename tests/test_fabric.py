"""Chip-level fabric: topology sizing, mapper round-trips, pipeline
invariants, and the paper's iso-area throughput-recovery claim."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cim_linear import CiMConfig, cim_linear, cim_matmul
from repro.core.schedule import pair_sar_schedule
from repro.fabric import (
    FabricConfig,
    arrays_for_area,
    execute_linear,
    execute_matmul,
    fabric_report,
    fabric_throughput,
    iso_area_comparison,
    map_matmul,
    map_model,
    model_matmuls,
    pipelined_schedule,
    render_markdown,
)
from repro.configs.registry import get_config


# ---------------------------------------------------------------------------
# topology
# ---------------------------------------------------------------------------


def test_topology_group_structure():
    assert FabricConfig(mode="pair_sar", n_arrays=8).group_size == 2
    hyb = FabricConfig(mode="hybrid", adc_bits=5, flash_bits=2, n_cim_per_group=3)
    assert hyb.group_size == 3 + 3
    fl = FabricConfig(mode="flash", adc_bits=5, n_cim_per_group=2, n_arrays=66)
    assert fl.group_size == 2 + 31
    assert FabricConfig(mode="conventional_sar", n_arrays=4).group_size == 1


def test_topology_rounds_to_whole_groups():
    fb = FabricConfig(mode="hybrid", n_arrays=64)  # group of 6 -> 60 arrays
    assert fb.resolved_n_arrays() == 60
    assert fb.n_groups == 10
    assert fb.n_compute_arrays == 30


def test_topology_area_budget_sizing():
    fb = FabricConfig(mode="pair_sar", n_arrays=2)
    budget = 10 * fb.per_array_area_um2
    assert arrays_for_area(budget, fb) == 10
    # the dedicated-SAR ADC is ~25x the in-memory digitizer (Table I), so an
    # equal budget funds several-fold more collaborative arrays
    conv = FabricConfig(mode="conventional_sar", n_arrays=2)
    assert arrays_for_area(budget, conv) < arrays_for_area(budget, fb) / 3


def test_topology_validation():
    with pytest.raises(ValueError):
        FabricConfig(mode="nope")
    with pytest.raises(ValueError):
        FabricConfig(mode="hybrid", flash_bits=5, adc_bits=5)
    with pytest.raises(ValueError):
        FabricConfig(mode="flash", adc_bits=5, n_arrays=4)  # < one group


# ---------------------------------------------------------------------------
# mapper
# ---------------------------------------------------------------------------


def test_mapper_tile_cover():
    fb = FabricConfig(mode="pair_sar", rows=16, cols=32, n_arrays=8)
    p = map_matmul("l", m=3, k=40, n=70, fabric=fb)
    assert p.k_tiles == 3 and p.n_tiles == 3
    assert len(p.tiles) == 9
    # tiles exactly cover the weight matrix
    cover = np.zeros((40, 70), np.int32)
    for t in p.tiles:
        cover[t.k0 : t.k1, t.n0 : t.n1] += 1
    assert (cover == 1).all()
    # round-robin across the 8 compute arrays -> 2 rounds
    assert p.rounds == 2
    assert max(t.round for t in p.tiles) == 1


def test_mapper_residency_and_ema():
    small = FabricConfig(mode="pair_sar", rows=16, cols=32, n_arrays=4)
    big = FabricConfig(mode="pair_sar", rows=16, cols=32, n_arrays=64)
    p_small = map_matmul("l", 1, 64, 64, small)  # 4*2=8 tiles on 4 arrays
    p_big = map_matmul("l", 1, 64, 64, big)
    assert not p_small.resident and p_big.resident
    assert p_small.weight_load_bits == p_big.weight_load_bits  # one pass each
    assert p_small.conversions == p_big.conversions


def test_mapper_model_shapes():
    cfg = get_config("smollm-135m")
    mms = model_matmuls(cfg, tokens=4, block_only=True)
    names = [m[0] for m in mms]
    assert names == [
        "block.q_proj", "block.k_proj", "block.v_proj", "block.o_proj",
        "block.gate_proj", "block.up_proj", "block.down_proj",
    ]
    d = cfg.d_model
    assert mms[0][1:] == (4, d, cfg.n_heads * cfg.head_dim)
    assert mms[4][1:] == (4, d, cfg.d_ff)
    fb = FabricConfig(mode="hybrid", n_arrays=60)
    placements = map_model(cfg, fb, tokens=4, block_only=True)
    assert len(placements) == 7
    # every compute array index stays in range
    for p in placements:
        assert all(0 <= t.array < fb.n_compute_arrays for t in p.tiles)


# ---------------------------------------------------------------------------
# pipeline
# ---------------------------------------------------------------------------


def test_pipeline_pair_sar_matches_core_two_array_schedule():
    fb = FabricConfig(mode="pair_sar", adc_bits=5, n_arrays=2)
    ours = pipelined_schedule(fb, n_conversions=8)
    core = pair_sar_schedule(bits=5, n_conversions=8)
    assert ours.n_cycles == core.n_cycles
    assert (
        ours.conversions_per_cycle_per_array
        == core.conversions_per_cycle_per_array
    )


def test_pipeline_hybrid_wave_period():
    # Fig. 3 steady state: 1 compute + nc staggered compares + (B-f) SAR
    fb = FabricConfig(mode="hybrid", adc_bits=5, flash_bits=2, n_cim_per_group=3, n_arrays=6)
    s = pipelined_schedule(fb, n_conversions=30)
    assert s.n_cycles == (30 // 3) * (1 + 3 + 3)
    # no reference array is double-booked (flash_ref and ref_gen same cycle)
    busy = set()
    for sl in s.slots:
        if sl.array.startswith("R"):
            assert (sl.cycle, sl.array) not in busy, (sl.cycle, sl.array)
            busy.add((sl.cycle, sl.array))


def test_pipeline_conventional_rates():
    sar = fabric_throughput(FabricConfig(mode="conventional_sar", adc_bits=5, n_arrays=4))
    fl = fabric_throughput(FabricConfig(mode="conventional_flash", adc_bits=5, n_arrays=4))
    assert sar["conversions_per_cycle_per_array"] == pytest.approx(1 / 5, rel=0.05)
    assert fl["conversions_per_cycle_per_array"] == pytest.approx(1.0, rel=0.05)


def test_iso_area_throughput_recovery():
    """The paper's system claim: at equal chip area the in-memory fabric's
    extra arrays more than recover the halved per-array throughput."""
    for mode in ("pair_sar", "hybrid"):
        iso = iso_area_comparison(FabricConfig(mode=mode, adc_bits=5, n_arrays=120))
        assert iso["array_count_ratio"] > 2.0, (mode, iso)
        assert iso["throughput_ratio"] >= 1.0, (mode, iso)
        assert iso["adc_area_ratio"] > 24, (mode, iso)


# ---------------------------------------------------------------------------
# execute: mapped == unmapped
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["bitplane", "fake_quant"])
def test_execute_roundtrip_exact(mode):
    fb = FabricConfig(mode="hybrid", rows=16, cols=32, n_arrays=12)
    cim = CiMConfig(mode=mode, a_bits=4, w_bits=4, adc_bits=5, rows=16, ste=False)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 3, 40))  # batched leading dims
    w = jax.random.normal(jax.random.fold_in(key, 1), (40, 70))
    y_map = execute_matmul(x, w, fb, cim, use_kernel=False)
    y_un = cim_matmul(x, w, cim)
    assert y_map.shape == y_un.shape == (2, 3, 70)
    np.testing.assert_array_equal(np.asarray(y_map), np.asarray(y_un))


def test_execute_linear_bias_and_kernel_path():
    fb = FabricConfig(mode="pair_sar", rows=16, cols=32, n_arrays=8)
    cim = CiMConfig(mode="fake_quant", a_bits=8, w_bits=8, adc_bits=5, rows=16, ste=False)
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (4, 48))
    w = jax.random.normal(jax.random.fold_in(key, 1), (48, 40))
    b = jax.random.normal(jax.random.fold_in(key, 2), (40,))
    y_map = execute_linear(x, w, b, fabric=fb, cim=cim)  # Pallas kernel path
    y_un = cim_linear(x, w, b, cfg=cim)
    np.testing.assert_allclose(np.asarray(y_map), np.asarray(y_un), atol=1e-4, rtol=1e-5)


def test_execute_bitplane_exactness_vs_integer_matmul():
    """2^adc_bits >= 2*rows -> the whole mapped chip is an exact int matmul."""
    from repro.core.cim_linear import quantize_symmetric

    fb = FabricConfig(mode="hybrid", rows=16, cols=32, n_arrays=12)
    cim = CiMConfig(mode="bitplane", a_bits=3, w_bits=3, adc_bits=5, rows=16, ste=False)
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (3, 32))
    w = jax.random.normal(jax.random.fold_in(key, 1), (32, 64))
    y = execute_matmul(x, w, fb, cim)
    xi, sx = quantize_symmetric(x, 3, True)
    wi, sw = quantize_symmetric(w, 3, True, per_axis=-1)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray((xi @ wi) * sx * sw), rtol=1e-4, atol=1e-5
    )


@pytest.mark.parametrize("use_kernel", [True, False])
def test_execute_fake_quant_stats_are_analytic(use_kernel):
    """return_stats=True is meaningful in fake_quant mode (both the Pallas
    kernel and the surrogate path): conversions are counted analytically —
    plane-pairs x M x k-tiles x N — matching the placement's counter and
    the bitplane path's actual count."""
    fb = FabricConfig(mode="pair_sar", rows=16, cols=32, n_arrays=8)
    cim = CiMConfig(mode="fake_quant", a_bits=4, w_bits=4, adc_bits=5, rows=16, ste=False)
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (3, 40))
    w = jax.random.normal(jax.random.fold_in(key, 1), (40, 70))
    _, st = execute_matmul(x, w, fb, cim, return_stats=True, use_kernel=use_kernel)
    p = map_matmul("l", 3, 40, 70, fb, cim=cim)
    assert int(st.conversions) == p.conversions > 0
    assert int(st.comparisons) > 0
    # the bitplane path performs exactly that many conversions for real
    cim_bp = CiMConfig(mode="bitplane", a_bits=4, w_bits=4, adc_bits=5, rows=16, ste=False)
    _, st_bp = execute_matmul(x, w, fb, cim_bp, return_stats=True)
    assert int(st_bp.conversions) == int(st.conversions)


def test_execute_rejects_wrong_modes_and_rows():
    fb = FabricConfig(mode="pair_sar", rows=16, n_arrays=2)
    x = jnp.zeros((2, 16))
    w = jnp.zeros((16, 8))
    with pytest.raises(ValueError):
        execute_matmul(x, w, fb, CiMConfig(mode="exact"))
    with pytest.raises(ValueError):
        map_matmul("l", 2, 16, 8, fb, cim=CiMConfig(mode="bitplane", rows=32))


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------


def test_fabric_report_rollup_and_ratios():
    cfg = get_config("smollm-135m")
    fb = FabricConfig(mode="hybrid", n_arrays=252)
    placements = map_model(cfg, fb, tokens=4, block_only=True)
    rep = fabric_report(placements, fb)
    assert len(rep["layers"]) == 7
    assert rep["totals"]["conversions"] == sum(p.conversions for p in placements)
    assert rep["paper_ratios"]["adc_area_ratio_vs_sar"] > 24
    assert rep["paper_ratios"]["adc_area_ratio_vs_flash"] > 50
    assert rep["iso_area"]["throughput_ratio"] >= 1.0
    md = render_markdown(rep)
    assert "block.q_proj" in md and "iso-area" in md


def test_fabric_report_ema_uses_model_residency():
    """A layer that fits by itself still reloads when the model doesn't fit:
    steady-state EMA must key off whole-model residency, not per-layer."""
    fb = FabricConfig(mode="pair_sar", rows=16, cols=32, n_arrays=64)
    layers = [map_matmul(f"l{i}", 1, 64, 256, fb) for i in range(10)]  # 32 tiles each
    assert all(p.resident for p in layers)  # each fits alone on 64 arrays
    rep = fabric_report(layers, fb)
    assert not rep["totals"]["model_resident"]  # 320 tiles > 64 arrays
    weight_bits = rep["totals"]["weight_program_bits"]
    assert rep["totals"]["ema_bits_per_pass"] >= weight_bits > 0
    # a chip that DOES hold the whole model drops the weight re-fetch term
    big = FabricConfig(mode="pair_sar", rows=16, cols=32, n_arrays=320)
    rep_big = fabric_report([map_matmul(f"l{i}", 1, 64, 256, big) for i in range(10)], big)
    assert rep_big["totals"]["model_resident"]
    assert rep_big["totals"]["ema_bits_per_pass"] < weight_bits


def test_fabric_report_conventional_has_no_ratios():
    fb = FabricConfig(mode="conventional_sar", n_arrays=16)
    p = [map_matmul("l", 1, 32, 32, fb)]
    rep = fabric_report(p, fb)
    assert "paper_ratios" not in rep and "iso_area" not in rep


def test_model_forward_graph_is_well_formed():
    """Every graph node consumes already-produced values with matching
    feature widths — the dataflow invariant the fused executor relies on."""
    from repro.fabric import model_forward_graph

    for arch in ("smollm-135m", "qwen3-moe-30b-a3b"):
        g = model_forward_graph(get_config(arch), 4, block_only=True)
        widths = {"x": g.d_in}
        for nd in g.nodes:
            assert all(i in widths for i in nd.inputs), nd.name
            if nd.op == "matmul":
                assert widths[nd.inputs[0]] == nd.k, nd.name
                widths[nd.name] = nd.n
            elif nd.op == "attention":
                q, k, v = nd.inputs
                assert widths[q] == nd.n_heads * nd.head_dim
                assert widths[k] == widths[v] == nd.n_kv_heads * nd.head_dim
                widths[nd.name] = nd.n_heads * nd.head_dim
            elif nd.op == "norm":
                assert widths[nd.inputs[0]] == nd.d
                widths[nd.name] = nd.d
            elif nd.op in ("silu_gate", "residual"):
                a, b = (widths[i] for i in nd.inputs)
                assert a == b, nd.name
                widths[nd.name] = a
            elif nd.op == "moe_gate":
                widths[nd.name] = widths[nd.inputs[0]]
            else:
                raise AssertionError(f"unknown op {nd.op}")
        assert g.output in widths
