"""Optimizers: reference math, convergence, factored state shapes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.adafactor import adafactor_init, adafactor_update
from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.schedules import warmup_cosine, warmup_linear


def test_adamw_matches_reference_step():
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.1, 0.2])}
    st = adamw_init(p)
    newp, st2, _ = adamw_update(g, st, p, lr=0.1, b1=0.9, b2=0.999,
                                eps=1e-8, weight_decay=0.0, grad_clip=None)
    # after bias correction, first step ≈ -lr * sign-ish update
    m = 0.1 * np.array([0.1, 0.2]) / (1 - 0.9)
    v = 0.001 * np.array([0.01, 0.04]) / (1 - 0.999)
    want = np.array([1.0, -2.0]) - 0.1 * m / (np.sqrt(v) + 1e-8)
    np.testing.assert_allclose(np.asarray(newp["w"]), want, rtol=1e-5)
    assert int(st2.count) == 1


def test_grad_clip_scales_global_norm():
    p = {"w": jnp.zeros(4)}
    g = {"w": jnp.full(4, 100.0)}
    st = adamw_init(p)
    _, _, mets = adamw_update(g, st, p, lr=0.0, grad_clip=1.0)
    assert float(mets["grad_norm"]) == pytest.approx(200.0)


@pytest.mark.parametrize("opt", ["adamw", "adafactor"])
def test_optimizers_descend_quadratic(opt):
    target = jnp.asarray(np.random.default_rng(0).normal(size=(16, 8)).astype(np.float32))
    p = {"w": jnp.zeros((16, 8))}
    init, upd = (adamw_init, adamw_update) if opt == "adamw" else (adafactor_init, adafactor_update)
    st = init(p)
    loss0 = None
    for i in range(60):
        loss, g = jax.value_and_grad(lambda p: jnp.mean((p["w"] - target) ** 2))(p)
        if loss0 is None:
            loss0 = float(loss)
        p, st, _ = upd(g, st, p, lr=0.05)
    assert float(loss) < 0.2 * loss0


def test_adafactor_state_is_factored():
    p = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((64,))}
    st = adafactor_init(p)
    assert st.v_row["w"].shape == (64,)
    assert st.v_col["w"].shape == (32,)
    assert st.v_full["b"].shape == (64,)
    # factored state is ~(64+32)/2048 of Adam's per-element state
    adam_bytes = 2 * 64 * 32
    fact_bytes = 64 + 32 + 1
    assert fact_bytes < adam_bytes / 20


def test_schedules():
    assert float(warmup_cosine(jnp.asarray(0), 1.0, 10, 100)) == 0.0
    assert float(warmup_cosine(jnp.asarray(10), 1.0, 10, 100)) == pytest.approx(1.0)
    assert float(warmup_cosine(jnp.asarray(100), 1.0, 10, 100)) == pytest.approx(0.1)
    assert float(warmup_linear(jnp.asarray(100), 1.0, 10, 100)) == pytest.approx(0.0, abs=1e-6)
