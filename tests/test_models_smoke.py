"""Per-assigned-architecture smoke tests: reduced config, one forward/train
step on CPU, output shapes + no NaNs (assignment requirement)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, reduced
from repro.models import build_model
from repro.models import layers as L

ARCH_NAMES = sorted(ARCHS)


@pytest.fixture(scope="module")
def _cache():
    return {}


def _build(name, _cache):
    if name not in _cache:
        cfg = reduced(ARCHS[name])
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        _cache[name] = (cfg, model, params)
    return _cache[name]


def _inputs(cfg, b, s, seed=1):
    if cfg.input_kind == "embeddings":
        return jax.random.normal(jax.random.PRNGKey(seed), (b, s, cfg.d_model))
    return jax.random.randint(jax.random.PRNGKey(seed), (b, s), 0, cfg.vocab)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_and_train_step(name, _cache):
    cfg, model, params = _build(name, _cache)
    b, s = 2, 64
    batch = {
        "inputs": _inputs(cfg, b, s),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, cfg.vocab),
    }
    h, aux = model.forward(params, batch["inputs"])
    assert h.shape == (b, s, cfg.d_model)
    assert bool(jnp.isfinite(h).all()), f"{name}: NaN in hidden states"

    loss, mets = jax.jit(model.loss_fn)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{name}: non-finite loss"

    grads = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
    assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads)), (
        f"{name}: non-finite grads"
    )


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_decode_consistency(name, _cache):
    """prefill(x[:-1]) + decode(x[-1]) == forward(x) at the last position.

    MoE archs use a drop-free capacity factor here: capacity-based token
    dropping legitimately differs between a 127-token prefill and a 1-token
    decode, so exact consistency only holds without drops."""
    import dataclasses

    from repro.models import build_model as _bm

    cfg, model, params = _build(name, _cache)
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=64.0)
        model = _bm(cfg)
    b, s = 2, 64
    x = _inputs(cfg, b, s, seed=3)
    cache = model.make_cache(b, s)
    _, cache = model.prefill(params, x[:, : s - 1], cache)
    last = x[:, s - 1]
    ld, _ = model.decode_step(params, last, jnp.asarray(s - 1), cache)
    h, _ = model.forward(params, x)
    lfull = L.logits_step(params["embed"], h[:, -1:, :], cfg)
    err = float(jnp.abs(ld - lfull).max())
    tol = 5e-3 if ARCHS[name].n_experts else 1e-4
    assert err < tol, f"{name}: decode/forward mismatch {err}"


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_param_count_analytic_matches(name, _cache):
    """configs.n_params() agrees with the actual reduced-param tree."""
    cfg, model, params = _build(name, _cache)
    actual = sum(x.size for x in jax.tree.leaves(params))
    analytic = cfg.n_params()
    # conv/dt/gating leaves make mamba counts approximate; dense exact-ish
    assert abs(actual - analytic) / analytic < 0.35, (name, actual, analytic)


def test_full_configs_match_reported_sizes():
    expected = {
        "llama3-405b": 405e9,
        "command-r-plus-104b": 104e9,
        "qwen2.5-32b": 32e9,
        "qwen3-moe-30b-a3b": 30e9,
        "pixtral-12b": 12e9,
        "zamba2-7b": 7e9,
        "mamba2-130m": 130e6,
        "smollm-135m": 135e6,
    }
    for name, want in expected.items():
        got = ARCHS[name].n_params()
        assert abs(got - want) / want < 0.25, (name, got, want)


def test_moe_active_params():
    cfg = ARCHS["qwen3-moe-30b-a3b"]
    assert abs(cfg.n_active_params() - 3.3e9) / 3.3e9 < 0.3
