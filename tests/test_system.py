"""End-to-end system behaviour: the paper's MNIST-CiM pipeline + the
framework integration of memory-immersed digitization."""

import dataclasses

import numpy as np
import pytest

from repro.core.cim_linear import CiMConfig
from repro.core.noise import AnalogEnv
from repro.train.mnist_mlp import evaluate, train_mlp


@pytest.fixture(scope="module")
def trained():
    params, acc = train_mlp(epochs=4)
    return params, acc


def test_float_accuracy(trained):
    _, acc = trained
    assert acc > 0.93, f"float MLP should exceed 93%, got {acc:.3f}"


def test_cim_5bit_accuracy_close_to_float(trained):
    """Paper's operating point: 16-row arrays, 5-bit in-memory SAR ADC."""
    params, float_acc = trained
    cim = CiMConfig(mode="bitplane", a_bits=4, w_bits=4, adc_bits=5,
                    rows=16, a_signed=False, ste=False)
    acc = evaluate(params, cim, n_eval=512)
    assert acc > float_acc - 0.05, f"5-bit CiM dropped too much: {acc:.3f} vs {float_acc:.3f}"


def test_asym_search_same_accuracy_fewer_comparisons(trained):
    """Fig. 4: the asymmetric search must not change accuracy (same codes)."""
    params, _ = trained
    base = dict(mode="bitplane", a_bits=4, w_bits=4, adc_bits=5,
                rows=16, a_signed=False, ste=False)
    acc_sym = evaluate(params, CiMConfig(search="sar", **base), n_eval=512)
    acc_asym = evaluate(params, CiMConfig(search="sar_asym", **base), n_eval=512)
    assert abs(acc_sym - acc_asym) < 1e-6


def test_accuracy_degrades_at_high_frequency(trained):
    """Fig. 7c: accuracy collapses when the clock outruns settling."""
    params, _ = trained
    cim = CiMConfig(mode="bitplane", a_bits=4, w_bits=4, adc_bits=5,
                    rows=16, a_signed=False, ste=False)
    acc_10mhz = evaluate(params, cim, env=AnalogEnv(freq_hz=10e6), n_eval=256)
    acc_100mhz = evaluate(params, cim, env=AnalogEnv(freq_hz=100e6), n_eval=256)
    assert acc_10mhz > acc_100mhz + 0.1


def test_accuracy_degrades_at_low_voltage(trained):
    """Fig. 7d: relative comparator noise grows as VDD drops."""
    params, _ = trained
    cim = CiMConfig(mode="bitplane", a_bits=4, w_bits=4, adc_bits=5,
                    rows=16, a_signed=False, ste=False)
    acc_1v = evaluate(params, cim, env=AnalogEnv(vdd=1.0), n_eval=256)
    acc_p6v = evaluate(params, cim, env=AnalogEnv(vdd=0.55), n_eval=256)
    assert acc_1v >= acc_p6v - 0.02


def test_fake_quant_tracks_bitplane(trained):
    """The fast surrogate stays within a few % of the faithful simulation."""
    params, _ = trained
    base = dict(a_bits=8, w_bits=8, adc_bits=8, rows=64, a_signed=False, ste=False)
    acc_fast = evaluate(params, CiMConfig(mode="fake_quant", **base), n_eval=512)
    acc_faithful = evaluate(params, CiMConfig(mode="bitplane", **base), n_eval=512)
    assert abs(acc_fast - acc_faithful) < 0.06
