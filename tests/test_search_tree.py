"""Asymmetric search tree: optimality, structure, paper Fig. 4c claim."""

import itertools

import numpy as np
import pytest

from repro.core import search_tree as st
from repro.core.mav_stats import analytic_code_pmf, entropy_bits


def brute_force_optimal(pmf):
    """Exact optimal expected depth by enumerating alphabetic trees (tiny n)."""
    n = len(pmf)

    def best(lo, hi):
        if lo == hi:
            return 0.0
        mass = sum(pmf[lo : hi + 1])
        return min(best(lo, k - 1) + best(k, hi) for k in range(lo + 1, hi + 1)) + mass

    return best(0, n - 1)


@pytest.mark.parametrize("n", [2, 4, 5, 7, 8])
def test_optimal_matches_bruteforce(n):
    rng = np.random.default_rng(n)
    pmf = rng.dirichlet(np.ones(n))
    tree = st.optimal_tree(pmf)
    st.validate_tree(tree)
    got = tree.expected_depth(pmf)
    want = brute_force_optimal(list(pmf))
    assert got == pytest.approx(want, rel=1e-9)


def test_symmetric_tree_depth():
    for bits in (1, 2, 3, 5, 8):
        tree = st.symmetric_tree(bits)
        st.validate_tree(tree)
        assert (tree.depth == bits).all()


def test_paper_fig4c_claim():
    """Skewed MAV (16 rows, p=0.25) => ~3.7 comparisons at 5 bits vs 5."""
    pmf = analytic_code_pmf(rows=16, bits=5, p_discharge=0.25)
    opt = st.optimal_tree(pmf)
    sym = st.symmetric_tree(5)
    e_opt = opt.expected_depth(pmf)
    assert sym.expected_depth(pmf) == 5.0
    assert 3.5 <= e_opt <= 3.9, f"paper claims ~3.7, got {e_opt:.3f}"


def test_expected_depth_bounds():
    """entropy <= E[depth] <= bits for any code distribution."""
    rng = np.random.default_rng(0)
    for _ in range(20):
        pmf = rng.dirichlet(np.ones(32) * rng.uniform(0.1, 3))
        tree = st.optimal_tree(pmf)
        st.validate_tree(tree)
        e = tree.expected_depth(pmf)
        assert e <= 5.0 + 1e-9
        assert e >= entropy_bits(pmf) - 1e-9 or e >= 1.0


def test_weight_balanced_near_optimal():
    pmf = analytic_code_pmf(rows=16, bits=5)
    wb = st.weight_balanced_tree(pmf)
    opt = st.optimal_tree(pmf)
    st.validate_tree(wb)
    assert wb.expected_depth(pmf) <= opt.expected_depth(pmf) + 0.5


def test_uniform_pmf_recovers_symmetric_cost():
    pmf = np.full(32, 1 / 32)
    opt = st.optimal_tree(pmf)
    assert opt.expected_depth(pmf) == pytest.approx(5.0, abs=1e-9)
