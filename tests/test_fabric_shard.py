"""Multi-chip fabric sharding: mesh planning, divisibility fallbacks,
sharded execution numerics (sequential and shard_map backends), and the
cross-chip traffic rollup. ``tests/conftest.py`` forces 8 host devices so
the shard_map backend runs on a REAL multi-device mesh here."""

import jax
import numpy as np
import pytest

from repro.core.cim_linear import CiMConfig, quantize_symmetric, _bitplane_matmul
from repro.fabric import (
    ChipMeshConfig,
    FabricConfig,
    execute_matmul,
    execute_sharded_matmul,
    map_matmul,
    overlap_rounds,
    overlapped_mesh_latency,
    render_markdown,
    resolve_backend,
    shard_model,
    shard_placement,
    sharded_fabric_report,
)
from repro.fabric.shard import _chip_noise_key
from repro.configs.registry import get_config
from repro.launch import shardings as sh
from repro.launch.mesh import make_chip_mesh


FB = FabricConfig(mode="pair_sar", rows=16, cols=32, n_arrays=8)
CIM_BP = CiMConfig(mode="bitplane", a_bits=4, w_bits=4, adc_bits=5, rows=16, ste=False)


# ---------------------------------------------------------------------------
# mesh + config plumbing
# ---------------------------------------------------------------------------


def test_chip_mesh_config_basics():
    cm = ChipMeshConfig(data=2, model=2, fabric=FB)
    assert cm.n_chips == 4 and cm.shape == (2, 2)
    assert cm.total_area_um2() == pytest.approx(4 * FB.chip_area_um2())
    # model chips hold distinct K-slices; data chips hold copies
    assert cm.total_weight_capacity_bits() == 2 * FB.weight_capacity_bits()
    with pytest.raises(ValueError):
        ChipMeshConfig(data=0)
    with pytest.raises(ValueError):
        ChipMeshConfig(psum_bits=0)


def test_make_chip_mesh_abstract_fallback():
    """Meshes bigger than the host's devices still plan (AbstractMesh)."""
    mesh = make_chip_mesh(data=4, model=4)
    assert mesh.axis_names == ("data", "model")
    assert mesh.shape["data"] == 4 and mesh.shape["model"] == 4
    # spec_for works against it — the planning contract fabric.shard relies on
    assert sh.spec_for(mesh, (16, 8), ("tp", "dp"), "t") is not None


def test_make_chip_mesh_require_concrete():
    """Device validation happens up front, with an actionable message —
    not deep inside shard_map."""
    with pytest.raises(RuntimeError, match=r"needs 16 jax devices.*host has 8"):
        make_chip_mesh(data=4, model=4, require_concrete=True)
    mesh = make_chip_mesh(data=2, model=2, require_concrete=True)
    assert hasattr(mesh, "devices")  # concrete Mesh, not AbstractMesh
    with pytest.raises(ValueError):
        make_chip_mesh(data=0, model=2, require_concrete=True)


# ---------------------------------------------------------------------------
# shard planning: K-splits and fallbacks
# ---------------------------------------------------------------------------


def test_shard_k_split_divides():
    # 64/16 = 4 K-tiles over model=4 -> 1 tile per chip, batch 4 over data=2
    cm = ChipMeshConfig(data=2, model=4, fabric=FB)
    sp = shard_placement(map_matmul("l", 4, 64, 64, FB), cm)
    assert sp.k_splits == 4 and sp.d_splits == 2
    assert sp.chip.k_tiles == 1 and sp.chip.k == 16
    assert sp.chip.m == 2
    assert not sp.fallbacks
    assert sp.n_chips_active == 8


def test_shard_fallback_recorded_when_tiles_dont_divide():
    # k=40 -> 3 K-tiles, not divisible by model=2 -> replicate + record
    cm = ChipMeshConfig(model=2, fabric=FB)
    sp = shard_placement(map_matmul("odd", 4, 40, 64, FB), cm)
    assert sp.k_splits == 1
    assert len(sp.fallbacks) == 1 and "odd" in sp.fallbacks[0]
    assert sp.crosschip_bits_per_pass == 0  # replicated -> no reduce-scatter
    # batch fallback: m=3 not divisible by data=2
    sp2 = shard_placement(map_matmul("oddm", 3, 64, 64, FB), ChipMeshConfig(data=2, fabric=FB))
    assert sp2.d_splits == 1 and len(sp2.fallbacks) == 1


def test_shard_rejects_mismatched_fabric():
    other = FabricConfig(mode="hybrid", n_arrays=12)
    with pytest.raises(ValueError):
        shard_placement(map_matmul("l", 4, 64, 64, other), ChipMeshConfig(fabric=FB))


def test_shard_crosschip_traffic_model():
    cm = ChipMeshConfig(data=2, model=4, fabric=FB, psum_bits=24)
    sp = shard_placement(map_matmul("l", 4, 64, 64, FB), cm)
    # ring reduce-scatter: (C-1) * M * N * psum_bits in total
    assert sp.crosschip_bits_per_pass == 3 * 4 * 64 * 24
    assert sp.crosschip_energy_pj == pytest.approx(
        sp.crosschip_bits_per_pass * cm.link_pj_per_bit
    )
    assert sp.crosschip_latency_s > 0
    # single chip on the model axis -> zero cross-chip EMA
    sp1 = shard_placement(map_matmul("l", 4, 64, 64, FB), ChipMeshConfig(fabric=FB))
    assert sp1.crosschip_bits_per_pass == 0 and sp1.crosschip_latency_s == 0.0


# ---------------------------------------------------------------------------
# execution: 1x1 bit-exact, multi-chip equivalent
# ---------------------------------------------------------------------------


def test_execute_sharded_1x1_bit_exact_bitplane():
    """A 1x1-mesh sharded run performs the identical operation sequence to
    the unsharded fabric.execute path — bit-for-bit equal."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (4, 64))
    w = jax.random.normal(jax.random.fold_in(key, 1), (64, 48))
    y_shard = execute_sharded_matmul(x, w, ChipMeshConfig(fabric=FB), CIM_BP)
    y_ref = execute_matmul(x, w, FB, CIM_BP)
    assert (np.asarray(y_shard) == np.asarray(y_ref)).all()


def test_execute_sharded_1x1_bit_exact_with_noise_key():
    """Chip 0's per-tile noise keys coincide with the unsharded path's."""
    cim = CiMConfig(
        mode="bitplane", a_bits=4, w_bits=4, adc_bits=5, rows=16, ste=False,
        comparator_sigma=0.05,
    )
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (4, 64))
    w = jax.random.normal(jax.random.fold_in(key, 1), (64, 48))
    nk = jax.random.PRNGKey(7)
    y_shard = execute_sharded_matmul(x, w, ChipMeshConfig(fabric=FB), cim, key=nk)
    y_ref = execute_matmul(x, w, FB, cim, key=nk)
    assert (np.asarray(y_shard) == np.asarray(y_ref)).all()


@pytest.mark.parametrize("data,model", [(1, 2), (2, 1), (2, 2)])
def test_execute_sharded_multi_chip_matches_unsharded(data, model):
    """Global quantization scales + tile-boundary K-splits: the digital
    partial-sum combine reproduces the unsharded result (noiseless ADC)."""
    cm = ChipMeshConfig(data=data, model=model, fabric=FB)
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (2, 2, 64))  # batched leading dims
    w = jax.random.normal(jax.random.fold_in(key, 1), (64, 48))
    y_shard = execute_sharded_matmul(x, w, cm, CIM_BP)
    y_ref = execute_matmul(x, w, FB, CIM_BP)
    assert y_shard.shape == y_ref.shape == (2, 2, 48)
    np.testing.assert_allclose(np.asarray(y_shard), np.asarray(y_ref), atol=1e-4, rtol=1e-5)


def test_execute_sharded_fake_quant_and_stats():
    cim = CiMConfig(mode="fake_quant", a_bits=8, w_bits=8, adc_bits=5, rows=16, ste=False)
    cm = ChipMeshConfig(data=2, model=2, fabric=FB)
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (4, 64))
    w = jax.random.normal(jax.random.fold_in(key, 1), (64, 48))
    y_shard = execute_sharded_matmul(x, w, cm, cim)
    y_ref = execute_matmul(x, w, FB, cim, use_kernel=False)
    np.testing.assert_allclose(np.asarray(y_shard), np.asarray(y_ref), atol=1e-4, rtol=1e-5)
    # bitplane stats: conversions across the mesh equal the unsharded count
    y, st = execute_sharded_matmul(x, w, cm, CIM_BP, return_stats=True)
    _, st_ref = execute_matmul(x, w, FB, CIM_BP, return_stats=True)
    assert int(st.conversions) == int(st_ref.conversions)


def test_execute_sharded_rejects_bad_mode_and_shape():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64))
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 48))
    cm = ChipMeshConfig(fabric=FB)
    with pytest.raises(ValueError):
        execute_sharded_matmul(x, w, cm, CiMConfig(mode="exact"))
    sp = shard_placement(map_matmul("l", 4, 32, 48, FB), cm)
    with pytest.raises(ValueError):
        execute_sharded_matmul(x, w, cm, CIM_BP, sharded=sp)
    # a plan from a different mesh must not silently mis-slice K
    sp_ok = shard_placement(map_matmul("l", 4, 64, 48, FB), cm)
    other_mesh = ChipMeshConfig(fabric=FabricConfig(mode="pair_sar", rows=32, cols=32, n_arrays=8))
    with pytest.raises(ValueError):
        execute_sharded_matmul(x, w, other_mesh, CIM_BP, sharded=sp_ok)


# ---------------------------------------------------------------------------
# report: on-chip EMA vs cross-chip traffic
# ---------------------------------------------------------------------------


def test_sharded_report_single_chip_has_zero_crosschip_ema():
    cm = ChipMeshConfig(fabric=FabricConfig(mode="hybrid", n_arrays=60))
    sps = shard_model(get_config("smollm-135m"), cm, tokens=4, block_only=True)
    rep = sharded_fabric_report(sps, cm)
    assert rep["mesh"]["n_chips"] == 1
    assert rep["totals"]["crosschip_bits_per_pass"] == 0
    assert rep["totals"]["crosschip_energy_pj"] == 0.0
    # single-chip mesh rows match the unsharded per-chip accounting
    for r in rep["layers"]:
        assert r["k_splits"] == 1 and r["d_splits"] == 1


def test_sharded_report_mesh_separates_traffic_and_gains_residency():
    cfg = get_config("smollm-135m")
    fb = FabricConfig(mode="hybrid", n_arrays=252)
    one = ChipMeshConfig(fabric=fb)
    big = ChipMeshConfig(data=2, model=2, fabric=fb)
    rep1 = sharded_fabric_report(shard_model(cfg, one, tokens=4, block_only=True), one)
    rep4 = sharded_fabric_report(shard_model(cfg, big, tokens=4, block_only=True), big)
    # cross-chip traffic appears only on the mesh, priced separately from EMA
    assert rep4["totals"]["crosschip_bits_per_pass"] > 0
    assert rep4["totals"]["ema_bits_per_pass"] > 0
    # K-sharding shrinks every chip's tile load toward residency
    assert rep4["totals"]["tiles_per_chip"] < rep1["totals"]["tiles_per_chip"]
    # markdown shows the mesh header and the traffic column
    md = render_markdown(rep4)
    assert "cross-chip reduce-scatter" in md and "KxD split" in md
    assert "2x2 (data x model) = 4 chips" in md


def test_sharded_report_totals_consistency():
    cm = ChipMeshConfig(data=2, model=2, fabric=FabricConfig(mode="pair_sar", n_arrays=64))
    sps = [shard_placement(map_matmul(f"l{i}", 4, 64, 256, cm.fabric), cm) for i in range(3)]
    rep = sharded_fabric_report(sps, cm)
    assert rep["totals"]["crosschip_bits_per_pass"] == sum(
        sp.crosschip_bits_per_pass for sp in sps
    )
    assert rep["totals"]["conversions"] == sum(r["conversions"] for r in rep["layers"])


# ---------------------------------------------------------------------------
# execution backends: shard_map on a real device mesh vs the sequential loop
# ---------------------------------------------------------------------------


def test_backend_resolution_and_errors():
    cm = ChipMeshConfig(data=2, model=2, fabric=FB)
    sp = shard_placement(map_matmul("l", 4, 64, 64, FB), cm)
    assert resolve_backend(sp, "auto") == "shard_map"  # conftest forces 8 devices
    assert resolve_backend(sp, "sequential") == "sequential"
    # 1x1: nothing to parallelize — auto stays sequential, explicit runs SPMD
    sp1 = shard_placement(map_matmul("l", 4, 64, 64, FB), ChipMeshConfig(fabric=FB))
    assert resolve_backend(sp1, "auto") == "sequential"
    assert resolve_backend(sp1, "shard_map") == "shard_map"
    with pytest.raises(ValueError, match="unknown backend"):
        resolve_backend(sp, "bogus")
    # 16 chips > 8 devices: auto falls back, explicit shard_map explains why
    big = ChipMeshConfig(data=4, model=4, fabric=FB)
    sp_big = shard_placement(map_matmul("l", 16, 256, 64, FB), big)
    assert resolve_backend(sp_big, "auto") == "sequential"
    with pytest.raises(ValueError, match="shard_map backend unavailable"):
        resolve_backend(sp_big, "shard_map")
    # replication fallback (3 K-tiles on model=2): realized splits != mesh
    cmf = ChipMeshConfig(model=2, fabric=FB)
    spf = shard_placement(map_matmul("odd", 4, 40, 64, FB), cmf)
    assert spf.k_splits == 1
    assert resolve_backend(spf, "auto") == "sequential"
    with pytest.raises(ValueError, match="replication fallbacks"):
        resolve_backend(spf, "shard_map")


def test_shard_map_1x1_bit_exact_incl_noise():
    """The shard_map backend on a 1x1 device mesh is bit-for-bit the
    unsharded fabric.execute path, noiseless AND noisy ADC."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (4, 64))
    w = jax.random.normal(jax.random.fold_in(key, 1), (64, 48))
    cm = ChipMeshConfig(fabric=FB)
    y_sm = execute_sharded_matmul(x, w, cm, CIM_BP, backend="shard_map")
    y_ref = execute_matmul(x, w, FB, CIM_BP)
    assert (np.asarray(y_sm) == np.asarray(y_ref)).all()
    noisy = CiMConfig(
        mode="bitplane", a_bits=4, w_bits=4, adc_bits=5, rows=16, ste=False,
        comparator_sigma=0.05,
    )
    nk = jax.random.PRNGKey(7)
    y_sm = execute_sharded_matmul(x, w, cm, noisy, key=nk, backend="shard_map")
    y_ref = execute_matmul(x, w, FB, noisy, key=nk)
    assert (np.asarray(y_sm) == np.asarray(y_ref)).all()


@pytest.mark.parametrize("data,model", [(1, 2), (2, 1), (2, 2)])
def test_shard_map_matches_sequential(data, model):
    """On a forced multi-device host mesh the shard_map backend matches the
    sequential chip loop to float tolerance (identical per-chip noise keys;
    only the reduce order of the collective may differ)."""
    cm = ChipMeshConfig(data=data, model=model, fabric=FB)
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (4, 64))
    w = jax.random.normal(jax.random.fold_in(key, 1), (64, 48))
    y_sm, st_sm = execute_sharded_matmul(
        x, w, cm, CIM_BP, backend="shard_map", return_stats=True
    )
    y_seq, st_seq = execute_sharded_matmul(
        x, w, cm, CIM_BP, backend="sequential", return_stats=True
    )
    np.testing.assert_allclose(np.asarray(y_sm), np.asarray(y_seq), atol=1e-5, rtol=1e-6)
    assert int(st_sm.conversions) == int(st_seq.conversions)
    assert int(st_sm.comparisons) == int(st_seq.comparisons)
    # noisy ADC: same chip/tile key derivation on both backends
    noisy = CiMConfig(
        mode="bitplane", a_bits=4, w_bits=4, adc_bits=5, rows=16, ste=False,
        comparator_sigma=0.05,
    )
    nk = jax.random.PRNGKey(9)
    y_sm = execute_sharded_matmul(x, w, cm, noisy, key=nk, backend="shard_map")
    y_seq = execute_sharded_matmul(x, w, cm, noisy, key=nk, backend="sequential")
    np.testing.assert_allclose(np.asarray(y_sm), np.asarray(y_seq), atol=1e-4, rtol=1e-5)
    # the link-traffic model is planning-side: identical for both backends
    sp = shard_placement(map_matmul("matmul", 4, 64, 48, FB), cm)
    rep = sharded_fabric_report([sp], cm)
    assert rep["totals"]["crosschip_bits_per_pass"] == sp.crosschip_bits_per_pass


def test_ragged_runtime_batch_falls_back_to_sequential():
    """A runtime batch not divisible by the data axis can only run on the
    sequential loop (last shard takes the remainder): auto must fall back
    instead of crashing inside shard_map; explicit shard_map must explain."""
    cm = ChipMeshConfig(data=2, model=2, fabric=FB)
    sp = shard_placement(map_matmul("l", 4, 64, 48, FB), cm)
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (5, 64))  # 5 rows on a 2-way data axis
    w = jax.random.normal(jax.random.fold_in(key, 1), (64, 48))
    y_auto = execute_sharded_matmul(x, w, cm, CIM_BP, sharded=sp, backend="auto")
    y_seq = execute_sharded_matmul(x, w, cm, CIM_BP, sharded=sp, backend="sequential")
    assert (np.asarray(y_auto) == np.asarray(y_seq)).all()
    with pytest.raises(ValueError, match="not divisible by the data axis"):
        execute_sharded_matmul(x, w, cm, CIM_BP, sharded=sp, backend="shard_map")


def test_shard_map_fake_quant_matches_sequential():
    cim = CiMConfig(mode="fake_quant", a_bits=8, w_bits=8, adc_bits=5, rows=16, ste=False)
    cm = ChipMeshConfig(data=2, model=2, fabric=FB)
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (4, 64))
    w = jax.random.normal(jax.random.fold_in(key, 1), (64, 48))
    y_sm = execute_sharded_matmul(x, w, cm, cim, backend="shard_map")
    y_seq = execute_sharded_matmul(x, w, cm, cim, backend="sequential")
    np.testing.assert_allclose(np.asarray(y_sm), np.asarray(y_seq), atol=1e-5, rtol=1e-6)


# ---------------------------------------------------------------------------
# per-chip ADC noise keys (regression: chips must never share draws)
# ---------------------------------------------------------------------------


def test_chip_noise_keys_distinct():
    key = jax.random.PRNGKey(0)

    def kd(k):  # raw uint32 PRNG keys and new-style typed keys both compare
        return np.asarray(jax.random.key_data(k) if jax.dtypes.issubdtype(
            k.dtype, jax.dtypes.prng_key) else k)

    ks = [kd(_chip_noise_key(key, c)) for c in range(4)]
    # chip 0 keeps the caller's key (1x1 bit-exactness); all chips distinct
    assert (ks[0] == kd(key)).all()
    for i in range(4):
        for j in range(i + 1, 4):
            assert not (ks[i] == ks[j]).all(), (i, j)


def test_model_axis_chips_draw_independent_noise():
    """Two model-axis chips given IDENTICAL K-slices must produce different
    noisy partial sums — a shared/reused key would make the sharded result
    exactly twice chip 0's partial."""
    noisy = CiMConfig(
        mode="bitplane", a_bits=4, w_bits=4, adc_bits=5, rows=16, ste=False,
        comparator_sigma=0.2,
    )
    cm = ChipMeshConfig(model=2, fabric=FB)
    key = jax.random.PRNGKey(4)
    nk = jax.random.PRNGKey(11)
    xa = jax.random.normal(key, (4, 32))
    wa = jax.random.normal(jax.random.fold_in(key, 1), (32, 32))
    # duplicated K-halves: chip 0 and chip 1 see the same integer problem
    x = np.concatenate([np.asarray(xa), np.asarray(xa)], axis=1)
    w = np.concatenate([np.asarray(wa), np.asarray(wa)], axis=0)
    y = np.asarray(
        execute_sharded_matmul(jax.numpy.asarray(x), jax.numpy.asarray(w), cm, noisy, key=nk)
    )
    # what a shared/reused key would produce: 2x chip 0's noisy partial
    x_int, sx = quantize_symmetric(jax.numpy.asarray(x).reshape(-1, 64), 4, True)
    w_int, sw = quantize_symmetric(jax.numpy.asarray(w), 4, True, per_axis=-1)
    y0, _ = _bitplane_matmul(x_int[:, :32], w_int[:32], noisy, jax.random.fold_in(nk, 0))
    y_shared = np.asarray(2.0 * y0 * sx * sw)
    assert not np.allclose(y, y_shared, atol=1e-6), "chips reused the same noise key"
    # sanity: with a noiseless ADC the duplicated halves DO sum to 2x chip 0
    clean = CiMConfig(mode="bitplane", a_bits=4, w_bits=4, adc_bits=5, rows=16, ste=False)
    y_clean = np.asarray(
        execute_sharded_matmul(jax.numpy.asarray(x), jax.numpy.asarray(w), cm, clean)
    )
    y0c, _ = _bitplane_matmul(x_int[:, :32], w_int[:32], clean, None)
    np.testing.assert_allclose(y_clean, np.asarray(2.0 * y0c * sx * sw), atol=1e-5)


# ---------------------------------------------------------------------------
# pipeline: double-buffered reduce-scatter / conversion overlap
# ---------------------------------------------------------------------------


def test_overlap_rounds_math():
    # links fully hidden under the next layer's conversions
    assert overlap_rounds([1.0, 1.0, 1.0], [0.5, 0.5, 0.5]) == pytest.approx(3.5)
    # a link that outlasts the next layer's conversions stalls the pipe
    assert overlap_rounds([1.0, 1.0], [2.0, 0.0]) == pytest.approx(3.0)
    # degenerate cases
    assert overlap_rounds([], []) == 0.0
    assert overlap_rounds([2.0], [0.5]) == pytest.approx(2.5)
    with pytest.raises(ValueError):
        overlap_rounds([1.0], [1.0, 2.0])


def test_overlapped_mesh_latency_edge_cases():
    """Empty layer list, single layer (nothing to overlap), and
    link >= compute (fraction stays clamped to [0, 1])."""
    # empty: all-zero report, no division by zero
    r = overlapped_mesh_latency([])
    assert r == {
        "serial_latency_s": 0.0,
        "overlapped_latency_s": 0.0,
        "hidden_link_s": 0.0,
        "link_hidden_fraction": 0.0,
    }
    # single layer: nothing overlaps — serial == overlapped, nothing hidden
    cm = ChipMeshConfig(model=2, fabric=FB)
    one = [shard_placement(map_matmul("l", 4, 64, 64, FB), cm)]
    r1 = overlapped_mesh_latency(one)
    assert r1["overlapped_latency_s"] == pytest.approx(r1["serial_latency_s"])
    assert r1["hidden_link_s"] == pytest.approx(0.0)
    assert r1["link_hidden_fraction"] == 0.0
    # link >= compute: slow links dominate every round; the hidden fraction
    # is compute-bounded and must stay within [0, 1]
    slow = ChipMeshConfig(model=2, fabric=FB, link_bits_per_s=1e3)
    sps = [shard_placement(map_matmul(f"l{i}", 4, 64, 64, FB), slow) for i in range(3)]
    rs = overlapped_mesh_latency(sps)
    assert all(sp.crosschip_latency_s > 0 for sp in sps)
    compute_s = rs["serial_latency_s"] - sum(sp.crosschip_latency_s for sp in sps)
    assert sps[0].crosschip_latency_s >= compute_s / 3  # links really dominate
    assert 0.0 <= rs["link_hidden_fraction"] <= 1.0
    assert rs["overlapped_latency_s"] <= rs["serial_latency_s"]
    # pure math edges: link time fully hides compute-sized chunks only
    assert overlap_rounds([1.0, 1.0], [5.0, 5.0]) == pytest.approx(1.0 + 5.0 + 5.0)
    # a zero-link mesh hides nothing and reports fraction 0, not NaN
    r0 = overlapped_mesh_latency(
        [shard_placement(map_matmul("l", 4, 64, 64, FB), ChipMeshConfig(fabric=FB))]
    )
    assert r0["link_hidden_fraction"] == 0.0


def test_report_overlap_totals():
    cfg = get_config("smollm-135m")
    cm = ChipMeshConfig(data=2, model=2, fabric=FabricConfig(mode="hybrid", n_arrays=252))
    sps = shard_model(cfg, cm, tokens=4, block_only=True)
    rep = sharded_fabric_report(sps, cm)
    t = rep["totals"]
    ov = overlapped_mesh_latency(sps)
    assert t["latency_s_overlapped"] == pytest.approx(ov["overlapped_latency_s"])
    assert ov["serial_latency_s"] == pytest.approx(t["latency_s"])
    assert 0.0 < t["latency_s_overlapped"] <= t["latency_s"]
    # multi-layer mesh with real link time: some of it must be hidden
    assert t["crosschip_latency_hidden_s"] > 0
    assert 0.0 < t["link_hidden_fraction"] <= 1.0
    assert "double-buffered round overlap" in render_markdown(rep)
