"""Memory-immersed ADC: mode equivalence, staircase, DNL/INL (paper Fig. 6)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import adc
from repro.core import search_tree as st
from repro.core.mav_stats import analytic_code_pmf


@pytest.fixture(scope="module")
def ramp():
    return jnp.linspace(0.0, 0.999, 4096)


@pytest.mark.parametrize("bits", [3, 5, 6])
@pytest.mark.parametrize("mode", ["sar", "flash"])
def test_modes_match_ideal(ramp, bits, mode):
    cfg = adc.ADCConfig(bits=bits, mode=mode, n_ref_columns=max(32, 1 << bits))
    res = adc.convert(ramp, cfg)
    ideal = adc.quantize_ideal(ramp, bits)
    np.testing.assert_array_equal(np.asarray(res.codes), np.asarray(ideal))


def test_asym_tree_same_codes(ramp):
    """The asymmetric search changes the comparison COUNT, not the codes."""
    pmf = analytic_code_pmf(16, 5)
    tree = st.optimal_tree(pmf)
    cfg = adc.ADCConfig(bits=5, mode="sar_asym")
    res = adc.convert(ramp, cfg, tree=tree)
    ideal = adc.quantize_ideal(ramp, 5)
    np.testing.assert_array_equal(np.asarray(res.codes), np.asarray(ideal))
    # comparisons vary per code and average below 5 under the skewed pmf
    mav_like = jnp.asarray(
        np.random.default_rng(0).binomial(16, 0.25, 20000) / 16.0
    )
    r2 = adc.convert(mav_like, cfg, tree=tree)
    assert float(r2.comparisons.mean()) < 4.0


@pytest.mark.parametrize("flash_bits", [1, 2, 3])
def test_hybrid_codes_and_cycles(ramp, flash_bits):
    cfg = adc.ADCConfig(bits=5, mode="hybrid", flash_bits=flash_bits)
    res = adc.convert(ramp, cfg)
    ideal = adc.quantize_ideal(ramp, 5)
    np.testing.assert_array_equal(np.asarray(res.codes), np.asarray(ideal))
    # latency: 1 flash cycle + (bits - flash_bits) SAR cycles
    assert int(res.cycles.max()) == 1 + (5 - flash_bits)
    # energy: all 2^f - 1 flash comparators fire + SAR comparisons
    assert int(res.comparisons.max()) == (1 << flash_bits) - 1 + (5 - flash_bits)


def test_hybrid_with_asymmetric_fine_trees(ramp):
    """Hybrid + per-segment asymmetric trees (paper §II-C composition)."""
    pmf = analytic_code_pmf(16, 5)
    seg = 1 << 3  # 2 flash bits -> segments of 8 codes
    fine = []
    for s in range(4):
        p = pmf[s * seg : (s + 1) * seg]
        fine.append(st.optimal_tree(p / max(p.sum(), 1e-12)))
    cfg = adc.ADCConfig(bits=5, mode="hybrid", flash_bits=2)
    res = adc.convert(ramp, cfg, fine_trees=fine)
    ideal = adc.quantize_ideal(ramp, 5)
    np.testing.assert_array_equal(np.asarray(res.codes), np.asarray(ideal))


def test_staircase_monotonic_under_mismatch():
    cfg = adc.ADCConfig(bits=5, mode="sar", ref_mismatch_sigma=0.02)
    r, codes = adc.measure_transfer(cfg, key=jax.random.PRNGKey(0))
    assert (np.diff(codes) >= 0).all(), "staircase must stay monotonic"


def test_dnl_inl_zero_without_mismatch():
    cfg = adc.ADCConfig(bits=5, mode="sar")
    r, codes = adc.measure_transfer(cfg, n_points=1 << 14)
    dnl, inl = adc.dnl_inl(r, codes, cfg)
    assert np.nanmax(np.abs(dnl)) < 0.05
    assert np.nanmax(np.abs(inl)) < 0.05


def test_dnl_inl_paper_band():
    """Fig. 6: with the chip's cap matching, DNL/INL stay below 0.5 LSB."""
    cfg = adc.ADCConfig(bits=5, mode="sar", ref_mismatch_sigma=0.01)
    worst_dnl = worst_inl = 0.0
    for seed in range(5):
        r, codes = adc.measure_transfer(
            cfg, key=jax.random.PRNGKey(seed), n_points=1 << 14
        )
        dnl, inl = adc.dnl_inl(r, codes, cfg)
        worst_dnl = max(worst_dnl, np.nanmax(np.abs(dnl)))
        worst_inl = max(worst_inl, np.nanmax(np.abs(inl)))
    assert worst_dnl < 0.5 and worst_inl < 0.5


def test_comparator_noise_degrades_gracefully():
    cfg_clean = adc.ADCConfig(bits=5, mode="sar")
    cfg_noisy = adc.ADCConfig(bits=5, mode="sar", comparator_sigma=0.02)
    v = jax.random.uniform(jax.random.PRNGKey(1), (20000,))
    c0 = adc.convert(v, cfg_clean).codes
    c1 = adc.convert(v, cfg_noisy, key=jax.random.PRNGKey(2)).codes
    err = np.abs(np.asarray(c0) - np.asarray(c1))
    assert err.mean() < 1.5  # noise shifts codes by ~sigma/LSB, not wildly
    assert (err > 0).any()  # but it does perturb


def test_reference_ladder_monotonic():
    for seed in range(4):
        cfg = adc.ADCConfig(bits=5, ref_mismatch_sigma=0.05)
        lad = adc.make_reference_ladder(cfg, jax.random.PRNGKey(seed))
        assert (jnp.diff(lad) > 0).all()
        assert float(lad[0]) == 0.0
        assert float(lad[-1]) == pytest.approx(cfg.vdd)


def test_invalid_configs_raise():
    with pytest.raises(ValueError):
        adc.ADCConfig(bits=6, n_ref_columns=32)  # needs 64 columns
    with pytest.raises(ValueError):
        adc.ADCConfig(mode="nope")
    with pytest.raises(ValueError):
        adc.ADCConfig(mode="hybrid", flash_bits=5, bits=5)
