"""Data pipelines: determinism, seekability, learnable structure."""

import numpy as np

from repro.data.mnist_synth import load_mnist_synth
from repro.data.tokens import TokenPipeline


def test_tokens_deterministic_and_seekable():
    p = TokenPipeline(vocab=100, seq_len=32, global_batch=8, seed=1)
    b1 = p.batch(step=7)
    b2 = p.batch(step=7)
    np.testing.assert_array_equal(b1["inputs"], b2["inputs"])
    b3 = p.batch(step=8)
    assert (b1["inputs"] != b3["inputs"]).any()


def test_tokens_dp_sharding_partitions_batch():
    p = TokenPipeline(vocab=100, seq_len=16, global_batch=8, seed=0)
    shards = [p.batch(0, r, 4) for r in range(4)]
    assert all(s["inputs"].shape == (2, 16) for s in shards)
    # distinct ranks produce distinct data
    assert (shards[0]["inputs"] != shards[1]["inputs"]).any()


def test_tokens_structure_learnable():
    """~p_struct of transitions follow the affine chain."""
    p = TokenPipeline(vocab=100, seq_len=256, global_batch=16, seed=0, p_struct=0.8)
    b = p.batch(0)
    toks = np.concatenate([b["inputs"], b["labels"][:, -1:]], axis=1)
    chain = (7 * toks[:, :-1] + 3) % 100
    frac = (toks[:, 1:] == chain).mean()
    assert 0.75 < frac < 0.86


def test_labels_are_next_tokens():
    p = TokenPipeline(vocab=50, seq_len=16, global_batch=4)
    b = p.batch(3)
    np.testing.assert_array_equal(b["inputs"][:, 1:], b["labels"][:, :-1])


def test_mnist_shapes_and_determinism():
    x1, y1, xt1, yt1 = load_mnist_synth(n_train=256, n_test=64)
    x2, y2, _, _ = load_mnist_synth(n_train=256, n_test=64)
    np.testing.assert_array_equal(x1, x2)
    assert x1.shape == (256, 256) and y1.shape == (256,)
    assert x1.min() >= 0 and x1.max() <= 1
    assert set(np.unique(y1)) <= set(range(10))


def test_mnist_classes_separable():
    """Nearest-prototype classifier already >70%: structure is real."""
    x, y, xt, yt = load_mnist_synth(n_train=2048, n_test=512)
    protos = np.stack([x[y == c].mean(0) for c in range(10)])
    pred = np.argmin(
        ((xt[:, None, :] - protos[None]) ** 2).sum(-1), axis=1
    )
    assert (pred == yt).mean() > 0.7
