"""Full-transformer-block fused graph (``repro.fabric.graph`` +
``mapper.model_forward_graph``): node taxonomy, sibling-inclusive cost
rollups (the chain undercount regression), real-``init_transformer``-weight
bit-exactness of the fused program vs the per-node reference on 1x1 (noisy
ADC included), multi-chip agreement, the collective census vs the documented
budget, ragged-batch fallback, and per-node noise-key independence — plus
the scan-over-layers depth/config matrix (``scan_layers=True``): scanned vs
unrolled bit-exact on 1x1 across depths/families/tied-unembed (noisy ADC
included), float-tolerant on the forced 2x2 mesh, census == per-block
census × n_layers + tail at every depth, report totals unchanged scan vs
unroll, and scan-body noise-key independence.
``tests/conftest.py`` forces 8 host devices."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.configs.registry import get_config
from repro.core.cim_linear import CiMConfig
from repro.fabric import (
    ChipMeshConfig,
    FabricConfig,
    compile_graph_forward,
    execute_sharded_matmul,
    graph_eligibility,
    measure_forward,
    model_block_template,
    model_forward_chain,
    model_forward_graph,
    model_matmuls,
    per_node_forward,
    render_markdown,
    shard_forward_graph,
    shard_model,
    sharded_fabric_report,
    stack_block_weights,
    transformer_graph_weights,
    unstack_block_weights,
)
from repro.models.transformer import init_transformer

FB = FabricConfig(mode="pair_sar", rows=16, cols=32, n_arrays=8)
CIM_BP = CiMConfig(mode="bitplane", a_bits=4, w_bits=4, adc_bits=5, rows=16, ste=False)
NOISY = dataclasses.replace(CIM_BP, comparator_sigma=0.05)

# graph-eligible on a 2x2 mesh: every K tile-aligns (64/128 % (2*16) == 0)
# and q/kv heads (4/2) divide the model axis
CFG = ModelConfig(
    name="graph-test", family="dense", n_layers=2, d_model=64, vocab=64,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, pad_vocab_multiple=16,
    param_dtype="float32", compute_dtype="float32",
)
MOE = ModelConfig(
    name="graph-moe", family="moe", n_layers=1, d_model=64, vocab=64,
    n_heads=4, n_kv_heads=2, head_dim=16, n_experts=8, top_k=2,
    d_ff_expert=64, pad_vocab_multiple=16,
    param_dtype="float32", compute_dtype="float32",
)


@pytest.fixture(scope="module")
def real_weights():
    params = init_transformer(jax.random.PRNGKey(0), CFG)
    return transformer_graph_weights(params, CFG)


# ---------------------------------------------------------------------------
# graph extraction / taxonomy
# ---------------------------------------------------------------------------


def test_dense_block_graph_taxonomy():
    g = model_forward_graph(get_config("smollm-135m"), 4, block_only=True)
    assert [nd.name for nd in g.nodes] == [
        "block.ln1", "block.q_proj", "block.k_proj", "block.v_proj",
        "block.attn_mix", "block.o_proj", "block.attn_res", "block.ln2",
        "block.gate_proj", "block.up_proj", "block.silu", "block.down_proj",
        "block.mlp_res",
    ]
    assert g.output == "block.mlp_res"
    assert g.sibling_names() == ["block.k_proj", "block.v_proj", "block.up_proj"]
    # every matmul the graph emits is one of model_matmuls' linears, with
    # identical shapes — the graph never invents or resizes a matmul
    mm = {(n, m, k, nn) for n, m, k, nn in model_matmuls(
        get_config("smollm-135m"), 4, block_only=True)}
    assert set(g.matmuls()) == mm


def test_full_model_graph_ends_at_unembed_and_supersets_chain():
    cfg = get_config("smollm-135m")
    g = model_forward_graph(cfg, 2)
    assert g.output == "unembed"
    assert len(g.matmul_nodes) == 7 * cfg.n_layers + 1
    chain = {n for n, *_ in model_forward_chain(cfg, 2)}
    graph_names = {nd.name for nd in g.matmul_nodes}
    assert chain < graph_names  # strict superset: the siblings are back


def test_moe_graph_routes_one_expert():
    g = model_forward_graph(MOE, 2, block_only=True)
    names = [nd.name for nd in g.nodes]
    assert "block.router" in names and "block.moe_gate" in names
    assert "block.expert1.gate_proj" not in names  # ONE activated expert
    router = g.node("block.router")
    assert router.combine == "psum"  # softmax needs the whole expert axis
    assert all(nd.combine == "scatter" for nd in g.matmul_nodes
               if nd is not router)


def test_graph_rejects_non_matmul_families():
    with pytest.raises(ValueError, match="dense|moe"):
        model_forward_graph(get_config("mamba2-130m"), 2)


def test_collective_budget_shape():
    g = model_forward_graph(CFG, 8)
    b2 = g.collective_budget(2)
    # 7 scatters/block * 2 blocks + unembed; one trailing gather; 4
    # boundaries/block + unembed; psum: 2 norms/block + ln_f + 2 stats
    assert b2["reduce_scatter"] == 15 and b2["all_gather"] == 1
    assert b2["pmax"] == 9 and b2["psum"] == 7
    b1 = g.collective_budget(1)
    assert b1["reduce_scatter"] == 0 and b1["all_gather"] == 0
    assert b1["pmax"] == 9  # boundary pmaxes remain as counted no-ops


# ---------------------------------------------------------------------------
# satellite: the sibling undercount regression (chain vs graph rollup)
# ---------------------------------------------------------------------------


def test_graph_report_totals_exceed_chain_by_exactly_the_siblings():
    """The chain-driven rollup omitted k/v/up conversions and link bits;
    the graph rollup must exceed it by exactly the sibling placements'
    stats (fabric large enough that both stay model-resident, so the EMA
    delta is the siblings' activation streams + nothing residency-driven)."""
    cfg = CFG
    fb = FabricConfig(mode="pair_sar", rows=16, cols=32, n_arrays=256)
    cm = ChipMeshConfig(data=2, model=2, fabric=fb)
    graph, gsps = shard_forward_graph(cfg, cm, tokens=8, cim=CIM_BP)
    csps = shard_model(cfg, cm, tokens=8, cim=CIM_BP,
                       matmuls=model_forward_chain(cfg, 8))
    grep = sharded_fabric_report(gsps, cm, graph=graph)
    crep = sharded_fabric_report(csps, cm)
    assert grep["totals"]["model_resident"] and crep["totals"]["model_resident"]
    siblings = set(graph.sibling_names())
    sib_rows = [r for r in grep["layers"] if r["layer"] in siblings]
    assert len(sib_rows) == len(siblings) > 0
    for key in ("conversions", "crosschip_bits_per_pass", "ema_bits_per_pass",
                "weight_load_bits", "digitization_energy_pj"):
        gt, ct = grep["totals"], crep["totals"]
        tkey = {"weight_load_bits": "weight_program_bits"}.get(key, key)
        delta = sum(r[key] for r in sib_rows)
        assert gt[tkey] >= ct[tkey]
        assert gt[tkey] - ct[tkey] == pytest.approx(delta), key
    # the report carries the graph section with the documented budget
    assert grep["graph"]["collective_budget"] == graph.collective_budget(2)
    md = render_markdown(grep)
    assert "forward graph" in md and "sibling branch(es)" in md


# ---------------------------------------------------------------------------
# eligibility
# ---------------------------------------------------------------------------


def test_graph_eligibility_head_divisibility():
    # kv=1 head cannot split over model=2: mixing needs whole head groups
    cfg = dataclasses.replace(CFG, n_kv_heads=1)
    cm = ChipMeshConfig(model=2, fabric=FB)
    graph, sps = shard_forward_graph(cfg, cm, tokens=8, cim=CIM_BP)
    probs = graph_eligibility(graph, sps, cm)
    assert any("head groups" in p for p in probs)
    prog = compile_graph_forward(cfg, cm, CIM_BP, tokens=8)
    assert prog.backend == "sequential" and prog.problems
    with pytest.raises(ValueError, match="unavailable"):
        compile_graph_forward(cfg, cm, CIM_BP, tokens=8, backend="shard_map")


def test_compile_graph_forward_validates_cim_and_weights(real_weights):
    cm = ChipMeshConfig(fabric=FB)
    with pytest.raises(ValueError, match="ste=False"):
        compile_graph_forward(CFG, cm, CiMConfig(mode="bitplane", rows=16, ste=True))
    with pytest.raises(ValueError, match="bitplane|fake_quant"):
        compile_graph_forward(CFG, cm, CiMConfig(mode="exact", ste=False))
    prog = compile_graph_forward(CFG, cm, CIM_BP, tokens=8)
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 4, 64))
    ws = dict(real_weights)
    missing = dict(ws)
    missing.pop("layer0.k_proj")
    with pytest.raises(ValueError, match="missing graph weights"):
        prog(x, missing)
    bad = dict(ws)
    bad["layer0.q_proj"] = bad["layer0.q_proj"].T[:, :32]
    with pytest.raises(ValueError, match="expects weights"):
        prog(x, bad)
    with pytest.raises(ValueError, match="batch, seq, d"):
        prog(x.reshape(8, 64), ws)


# ---------------------------------------------------------------------------
# acceptance: real weights, >= 2 blocks, bit-exact on 1x1, matches on 2x2,
# census == budget
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cim,with_key", [(CIM_BP, False), (NOISY, True)])
def test_fused_graph_1x1_bit_exact_real_weights(real_weights, cim, with_key):
    """Acceptance: 2 transformer blocks of init_transformer weights through
    the fused graph are bit-for-bit the per-node reference on a 1x1 mesh —
    noisy ADC included (per-node fold_in keys shared by both paths)."""
    cm = ChipMeshConfig(fabric=FB)
    prog = compile_graph_forward(CFG, cm, cim, tokens=8)
    assert prog.backend == "shard_map"  # auto fuses even on one chip
    key = jax.random.PRNGKey(7) if with_key else None
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 64))
    y = prog(x, real_weights, key=key)
    y_ref = per_node_forward(
        x, real_weights, prog.graph, prog.placements, cm, cim, key=key,
        backend="sequential",
    )
    assert y.shape == (2, 4, CFG.padded_vocab)
    assert (np.asarray(y) == np.asarray(y_ref)).all()


def test_fused_graph_2x2_matches_and_census_equals_budget(real_weights):
    """Acceptance: forced-device 2x2 mesh agreement (noisy ADC), identical
    stats, and the collective census EQUAL to the documented budget — the
    per-sibling scatters are enumerated, with ONE trailing all-gather."""
    cm = ChipMeshConfig(data=2, model=2, fabric=FB)
    prog = compile_graph_forward(CFG, cm, NOISY, tokens=8)
    assert prog.backend == "shard_map"
    nk = jax.random.PRNGKey(9)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 64))
    y, st = prog(x, real_weights, key=nk, return_stats=True)
    y_ref, st_ref = prog.reference_forward(x, real_weights, key=nk,
                                           return_stats=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-5)
    assert int(st.conversions) == int(st_ref.conversions)
    assert int(st.comparisons) == int(st_ref.comparisons)
    counts = prog.collective_counts(key=nk)
    assert counts == prog.collective_budget()
    assert counts["all_gather"] == 1
    assert counts["reduce_scatter"] == 7 * CFG.n_layers + 1


def test_fused_graph_moe_and_fake_quant():
    cm = ChipMeshConfig(data=2, model=2, fabric=FB)
    params = init_transformer(jax.random.PRNGKey(0), MOE)
    ws = transformer_graph_weights(params, MOE)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 64))
    prog = compile_graph_forward(MOE, cm, CIM_BP, tokens=8)
    assert prog.backend == "shard_map"
    y = np.asarray(prog(x, ws))
    y_ref = np.asarray(prog.reference_forward(x, ws))
    np.testing.assert_allclose(y, y_ref, atol=1e-5, rtol=1e-6)
    assert prog.collective_counts() == prog.collective_budget()
    fq = CiMConfig(mode="fake_quant", a_bits=8, w_bits=8, adc_bits=5, rows=16,
                   ste=False)
    params_d = init_transformer(jax.random.PRNGKey(0), CFG)
    ws_d = transformer_graph_weights(params_d, CFG)
    progf = compile_graph_forward(CFG, cm, fq, tokens=8)
    yf = np.asarray(progf(x, ws_d))
    yf_ref = np.asarray(progf.reference_forward(x, ws_d))
    np.testing.assert_allclose(yf, yf_ref, atol=1e-5, rtol=1e-6)


# ---------------------------------------------------------------------------
# satellite: ragged batch fallback + per-node noise-key independence
# ---------------------------------------------------------------------------


def test_ragged_batch_falls_back_to_per_node_reference(real_weights):
    """batch=3 does not divide data=2: auto falls back to the per-node loop
    (bit-identical), an explicit shard_map request raises."""
    cm = ChipMeshConfig(data=2, model=2, fabric=FB)
    prog = compile_graph_forward(CFG, cm, CIM_BP, tokens=8)
    x3 = jax.random.normal(jax.random.PRNGKey(2), (3, 4, 64))
    y3 = prog(x3, real_weights)
    y3_ref = per_node_forward(
        x3, real_weights, prog.graph, prog.placements, cm, CIM_BP,
        backend="sequential",
    )
    assert (np.asarray(y3) == np.asarray(y3_ref)).all()
    strict = compile_graph_forward(CFG, cm, CIM_BP, tokens=8, backend="shard_map")
    with pytest.raises(ValueError, match="not divisible by the data axis"):
        strict(x3, real_weights)


def test_sibling_noise_keys_are_independent():
    """k_proj and v_proj have identical shapes and (here) identical weights
    and input; their ADC noise comes from fold_in(key, matmul_index) — node
    2 vs node 3 — so their noisy outputs must differ (no shared draws),
    while re-running either node's key reproduces its draws exactly."""
    cm = ChipMeshConfig(fabric=FB)
    graph, sps = shard_forward_graph(CFG, cm, tokens=8, cim=NOISY)
    sp = {s.name: s for s in sps}
    mm_names = [nd.name for nd in graph.matmul_nodes]
    ik, iv = mm_names.index("layer0.k_proj"), mm_names.index("layer0.v_proj")
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
    yk = execute_sharded_matmul(x, w, cm, NOISY, sharded=sp["layer0.k_proj"],
                                key=jax.random.fold_in(key, ik))
    yv = execute_sharded_matmul(x, w, cm, NOISY, sharded=sp["layer0.v_proj"],
                                key=jax.random.fold_in(key, iv))
    assert not (np.asarray(yk) == np.asarray(yv)).all()
    yk2 = execute_sharded_matmul(x, w, cm, NOISY, sharded=sp["layer0.k_proj"],
                                 key=jax.random.fold_in(key, ik))
    assert (np.asarray(yk) == np.asarray(yk2)).all()


# ---------------------------------------------------------------------------
# weights adapter + measure_forward
# ---------------------------------------------------------------------------


def test_transformer_graph_weights_adapter():
    params = init_transformer(jax.random.PRNGKey(0), CFG)
    ws = transformer_graph_weights(params, CFG)
    shapes = compile_graph_forward(CFG, ChipMeshConfig(fabric=FB), CIM_BP,
                                   tokens=8).weight_shapes()
    assert set(ws) == set(shapes)
    for name, shape in shapes.items():
        assert tuple(ws[name].shape) == shape, name
        assert ws[name].dtype == jnp.float32
    # block_only uses layer 0 under the block prefix, no unembed/ln_f
    wb = transformer_graph_weights(params, CFG, block_only=True)
    assert "unembed" not in wb and "block.q_proj" in wb
    assert (np.asarray(wb["block.q_proj"]) == np.asarray(ws["layer0.q_proj"])).all()
    # tied embeddings unembed via tok.T; qkv_bias is not mappable
    tied = dataclasses.replace(CFG, tie_embeddings=True)
    wt = transformer_graph_weights(init_transformer(jax.random.PRNGKey(0), tied), tied)
    assert wt["unembed"].shape == (CFG.d_model, CFG.padded_vocab)
    with pytest.raises(ValueError, match="qkv_bias"):
        transformer_graph_weights(params, dataclasses.replace(CFG, qkv_bias=True))


def test_measure_forward_on_graph_program(real_weights):
    cm = ChipMeshConfig(data=2, model=2, fabric=FB)
    prog = compile_graph_forward(CFG, cm, CIM_BP, tokens=8)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 64))
    meas = measure_forward(prog, x=x, weights=real_weights, iters=1,
                           per_layer_backend="sequential")
    assert meas["backend"] == "shard_map" and meas["n_chips"] == 4
    assert meas["fused_s"] > 0 and meas["per_layer_s"] > 0
    assert meas["modeled_link_s"] > 0  # model axis carries sibling bits too
    # a ragged batch cannot be traced by the fused twins: measure_forward
    # must skip the fused timings (__call__'s documented fallback) instead
    # of crashing inside shard_map
    assert not prog.fused_available(jnp.zeros((3, 4, 64)))
    meas3 = measure_forward(prog, x=x[:1], weights=real_weights, iters=1,
                            per_layer_backend="sequential")
    assert "fused_s" not in meas3 and meas3["per_layer_s"] > 0
    assert meas3["measured_collective_s"] is None


def test_serve_fabric_program_chain_fallback_for_mamba():
    """serve --fabric-program on a family without a matmul-graph forward
    (mamba/hybrid) validates via the fused CHAIN program — the graph path
    raising for those families must not leak out of serving."""
    mamba = get_config("mamba2-130m")
    assert mamba.family == "mamba"
    with pytest.raises(ValueError, match="dense|moe"):
        model_forward_graph(mamba, 2, block_only=True)
    from repro.fabric import compile_forward

    cm = ChipMeshConfig(fabric=FB)
    prog = compile_forward(mamba, cm, cim=CIM_BP, tokens=2, block_only=True)
    x = prog.example_input(jax.random.PRNGKey(2))
    ws = prog.random_weights(jax.random.PRNGKey(3))
    y = prog(x, ws)
    y_ref = prog.reference_forward(x, ws, backend="sequential")
    assert (np.asarray(y) == np.asarray(y_ref)).all()


# ---------------------------------------------------------------------------
# scan-over-layers: depth/config equivalence matrix + census scaling +
# noise-key independence + adapters (compile_graph_forward(scan_layers=True))
# ---------------------------------------------------------------------------


def _scan_cfg(family: str, n_layers: int, tied: bool) -> ModelConfig:
    base = CFG if family == "dense" else MOE
    return dataclasses.replace(
        base, n_layers=n_layers, tie_embeddings=tied,
        name=f"scan-{family}-{n_layers}-{int(tied)}",
    )


def _scan_pair(cfg, cm, cim):
    """(unrolled, scanned) programs plus matched real-weight dicts."""
    params = init_transformer(jax.random.PRNGKey(0), cfg)
    un = compile_graph_forward(cfg, cm, cim, tokens=8)
    sc = compile_graph_forward(cfg, cm, cim, tokens=8, scan_layers=True)
    return un, sc, transformer_graph_weights(params, cfg), stack_block_weights(params, cfg)


# one cell per matrix dimension at >= 2 depths (full cross product would be
# pure compile time): depth sweep on dense-untied, tied at 2 (dense) and 2
# (moe), moe at both its depths
SCAN_MATRIX = [
    ("dense", 1, False),
    ("dense", 2, False),
    ("dense", 2, True),
    ("dense", 5, False),
    ("moe", 1, False),
    ("moe", 2, True),
]


@pytest.mark.parametrize("family,n_layers,tied", SCAN_MATRIX)
def test_scan_matrix_bit_exact_1x1(family, n_layers, tied):
    """Acceptance matrix: the scanned program's logits are bit-for-bit the
    unrolled program's on a 1x1 mesh at every depth/family/tied combo, with
    real init_transformer weights through both adapters."""
    cfg = _scan_cfg(family, n_layers, tied)
    cm = ChipMeshConfig(fabric=FB)
    un, sc, wu, ws = _scan_pair(cfg, cm, CIM_BP)
    assert un.backend == sc.backend == "shard_map"
    assert sc.scan_layers and sc.n_blocks == n_layers
    assert not un.scan_layers
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 64))
    y_un, st_un = un(x, wu, return_stats=True)
    y_sc, st_sc = sc(x, ws, return_stats=True)
    assert y_sc.shape == (2, 4, cfg.padded_vocab)
    assert (np.asarray(y_un) == np.asarray(y_sc)).all()
    assert int(st_un.conversions) == int(st_sc.conversions)
    assert int(st_un.comparisons) == int(st_sc.comparisons)


@pytest.mark.parametrize("family,n_layers", [("dense", 2), ("moe", 1)])
def test_scan_noisy_bit_exact_1x1(family, n_layers):
    """Noisy-ADC acceptance: per-layer fold_in noise keys derived INSIDE the
    scan body reproduce the unrolled program's draws bit-for-bit."""
    cfg = _scan_cfg(family, n_layers, False)
    cm = ChipMeshConfig(fabric=FB)
    un, sc, wu, ws = _scan_pair(cfg, cm, NOISY)
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 64))
    assert (np.asarray(un(x, wu, key=key)) == np.asarray(sc(x, ws, key=key))).all()


@pytest.mark.parametrize(
    "family,n_layers",
    [("dense", 1), ("dense", 2), ("dense", 5), ("moe", 1), ("moe", 2)],
)
def test_scan_census_scaling(family, n_layers):
    """Census-scaling regression at every matrix depth: scanned
    collective_counts == per-block census × n_layers + tail == the unrolled
    budget — the jaxpr walk multiplies by the scan trip count, so the k/v/
    up/router reduce-scatters inside the body are never silently dropped.
    Trace-only (make_jaxpr): cheap at any depth."""
    cfg = _scan_cfg(family, n_layers, False)
    cm = ChipMeshConfig(data=2, model=2, fabric=FB)
    sc = compile_graph_forward(cfg, cm, CIM_BP, tokens=8, scan_layers=True)
    assert sc.backend == "shard_map"
    counts = sc.collective_counts()
    budget = sc.collective_budget()
    blk = sc.block_graph.block_census(cm.model)
    tail = sc.tail_graph.collective_budget(cm.model)
    assert counts == budget
    assert {k: blk[k] * n_layers + tail[k] for k in blk} == budget
    # per-block scatter census: 7 dense (q/k/v/o/gate/up/down) — the router
    # recombines via psum, so moe adds a psum, not a scatter
    assert blk["reduce_scatter"] == 7
    assert counts["reduce_scatter"] == 7 * n_layers + 1
    assert counts["all_gather"] == 1


@pytest.mark.parametrize("family,n_layers", [("dense", 2), ("moe", 1)])
def test_scan_2x2_matches_unrolled(family, n_layers):
    """Forced-device 2x2 mesh: scanned vs unrolled logits agree to float
    tolerance (noisy ADC), with identical conversion/comparison stats."""
    cfg = _scan_cfg(family, n_layers, False)
    cm = ChipMeshConfig(data=2, model=2, fabric=FB)
    un, sc, wu, ws = _scan_pair(cfg, cm, NOISY)
    assert un.backend == sc.backend == "shard_map"
    key = jax.random.PRNGKey(9)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 64))
    y_un, st_un = un(x, wu, key=key, return_stats=True)
    y_sc, st_sc = sc(x, ws, key=key, return_stats=True)
    np.testing.assert_allclose(np.asarray(y_un), np.asarray(y_sc),
                               atol=1e-4, rtol=1e-5)
    assert int(st_un.conversions) == int(st_sc.conversions)
    assert int(st_un.comparisons) == int(st_sc.comparisons)


def test_scan_report_totals_unchanged_and_scan_section():
    """Sibling-inclusive report totals are IDENTICAL scan vs unroll (the
    scan changes compile cost, not link traffic), and the scanned program
    threads its per-block decomposition into the graph section."""
    cm = ChipMeshConfig(data=2, model=2, fabric=FB)
    un = compile_graph_forward(CFG, cm, CIM_BP, tokens=8)
    sc = compile_graph_forward(CFG, cm, CIM_BP, tokens=8, scan_layers=True)
    rep_un = sharded_fabric_report(un.placements, cm, graph=un.graph, program=un)
    rep_sc = sharded_fabric_report(sc.placements, cm, graph=sc.graph, program=sc)
    assert rep_un["totals"] == rep_sc["totals"]
    assert rep_un["graph"]["collective_budget"] == rep_sc["graph"]["collective_budget"]
    assert "scan" not in rep_un["graph"]
    scan_sec = rep_sc["graph"]["scan"]
    assert scan_sec["n_blocks"] == CFG.n_layers
    blk, tail = scan_sec["block_census"], scan_sec["tail_budget"]
    assert {k: blk[k] * CFG.n_layers + tail[k] for k in blk} == (
        rep_sc["graph"]["collective_budget"]
    )
    md = render_markdown(rep_sc)
    assert "scanned: block traced once" in md
    assert "scanned" not in render_markdown(rep_un)


def test_scan_noise_keys_differ_across_iterations_and_match_unrolled():
    """The scan body's per-layer ADC noise draws (1) match the unrolled
    program's fold_in(key, global_matmul_index) derivation EXACTLY and
    (2) genuinely differ across scan iterations — a reference run whose
    key_fn reuses layer-0 keys for every layer diverges."""
    cm = ChipMeshConfig(fabric=FB)
    sc = compile_graph_forward(CFG, cm, NOISY, tokens=8, scan_layers=True)
    params = init_transformer(jax.random.PRNGKey(0), CFG)
    wu = transformer_graph_weights(params, CFG)
    ws = stack_block_weights(params, CFG)
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 64))
    y_sc = np.asarray(sc(x, ws, key=key))
    # (1) exact match with the unrolled per-node derivation
    y_ref = np.asarray(per_node_forward(
        x, wu, sc.graph, sc.placements, cm, NOISY, key=key,
    ))
    assert (y_sc == y_ref).all()
    # an explicit key_fn equal to the default is a no-op
    y_same = np.asarray(per_node_forward(
        x, wu, sc.graph, sc.placements, cm, NOISY, key=key,
        key_fn=jax.random.fold_in,
    ))
    assert (y_same == y_ref).all()
    # (2) collapsing every layer onto layer-0's keys changes the output:
    # the scanned body's draws are NOT shared across iterations
    mmb = len(sc.block_graph.matmul_nodes)
    y_shared = np.asarray(per_node_forward(
        x, wu, sc.graph, sc.placements, cm, NOISY, key=key,
        key_fn=lambda k, i: jax.random.fold_in(k, i % mmb),
    ))
    assert not (y_shared == y_ref).all()


def test_scan_ragged_batch_falls_back_with_stacked_weights():
    """A ragged batch on a scanned program unstacks the block weights and
    runs the per-node reference — bit-identical to the unrolled fallback."""
    cm = ChipMeshConfig(data=2, model=2, fabric=FB)
    sc = compile_graph_forward(CFG, cm, CIM_BP, tokens=8, scan_layers=True)
    params = init_transformer(jax.random.PRNGKey(0), CFG)
    wu = transformer_graph_weights(params, CFG)
    ws = stack_block_weights(params, CFG)
    x3 = jax.random.normal(jax.random.PRNGKey(2), (3, 4, 64))
    y3 = sc(x3, ws)
    y3_ref = per_node_forward(
        x3, wu, sc.graph, sc.placements, cm, CIM_BP, backend="sequential",
    )
    assert (np.asarray(y3) == np.asarray(y3_ref)).all()
    assert (np.asarray(sc.reference_forward(x3, ws)) == np.asarray(y3_ref)).all()


def test_scan_weight_adapters_roundtrip_and_shapes():
    """stack_block_weights slices == transformer_graph_weights entries;
    unstack is its exact inverse; weight_shapes and random_weights stack
    the per-layer form on the leading layer axis."""
    for cfg in (CFG, dataclasses.replace(CFG, tie_embeddings=True),
                dataclasses.replace(MOE, n_layers=2)):
        params = init_transformer(jax.random.PRNGKey(0), cfg)
        wu = transformer_graph_weights(params, cfg)
        ws = stack_block_weights(params, cfg)
        unrolled = unstack_block_weights(ws, cfg.n_layers)
        assert set(unrolled) == set(wu)
        for name in wu:
            assert (np.asarray(unrolled[name]) == np.asarray(wu[name])).all(), name
    cm = ChipMeshConfig(fabric=FB)
    sc = compile_graph_forward(CFG, cm, CIM_BP, tokens=8, scan_layers=True)
    un = compile_graph_forward(CFG, cm, CIM_BP, tokens=8)
    shapes = sc.weight_shapes()
    assert shapes["block.q_proj"] == (CFG.n_layers, 64, 64)
    assert shapes["block.ln1"] == (CFG.n_layers, 64)
    assert shapes["unembed"] == (64, CFG.padded_vocab)
    ws = stack_block_weights(init_transformer(jax.random.PRNGKey(0), CFG), CFG)
    assert {n: tuple(w.shape) for n, w in ws.items()} == shapes
    # same key -> corresponding random draws in both forms
    rs, ru = sc.random_weights(jax.random.PRNGKey(3)), un.random_weights(jax.random.PRNGKey(3))
    for i in range(CFG.n_layers):
        assert (np.asarray(rs["block.o_proj"][i])
                == np.asarray(ru[f"layer{i}.o_proj"])).all()
    # stacked-shape validation catches a per-layer-shaped weight
    bad = dict(ws)
    bad["block.q_proj"] = bad["block.q_proj"][0]
    with pytest.raises(ValueError, match="expects weights"):
        sc(jax.random.normal(jax.random.PRNGKey(1), (2, 4, 64)), bad)


def test_scan_error_paths_and_block_template():
    """scan_layers needs a ModelConfig and the full model; the block
    template pairs the repeated block with the ln_f/unembed tail."""
    cm = ChipMeshConfig(fabric=FB)
    graph = model_forward_graph(CFG, 8)
    with pytest.raises(ValueError, match="ModelConfig"):
        compile_graph_forward(graph, cm, CIM_BP, scan_layers=True)
    with pytest.raises(ValueError, match="block_only"):
        compile_graph_forward(CFG, cm, CIM_BP, scan_layers=True, block_only=True)
    block, tail = model_block_template(CFG, 8)
    assert block.output == "block.mlp_res"
    assert [nd.name for nd in tail.nodes] == ["ln_f", "unembed"]
    assert tail.node("unembed").n == CFG.padded_vocab
    # block census drops the trailing gather and the two stats psums that
    # only the full program pays once
    b = block.collective_budget(2)
    c = block.block_census(2)
    assert c["all_gather"] == 0 and c["psum"] == b["psum"] - 2
    assert c["reduce_scatter"] == b["reduce_scatter"]
