"""Assemble EXPERIMENTS.md from dry-run/hillclimb JSON + the narrative below.

  PYTHONPATH=src python tools/build_experiments.py
"""

import json
from pathlib import Path

import sys

sys.path.insert(0, "src")

from repro.roofline.report import collective_schedule, load, roofline_table  # noqa: E402

ROOT = Path(__file__).parent.parent
V3 = ROOT / "results/dryrun_v3"
V3_OPT = ROOT / "results/dryrun_v3_opt"
HC = ROOT / "results/hillclimb"


def _hc(name):
    f = HC / (name.replace("/", "__") + ".json")
    if not f.exists():
        return None
    r = json.loads(f.read_text())
    return r if r.get("status") == "ok" else None


def _cell(dir_, arch, shape, mesh="singlepod"):
    f = dir_ / f"{arch}__{shape}__{mesh}.json"
    if not f.exists():
        return None
    r = json.loads(f.read_text())
    return r if r.get("status") == "ok" else None


def _terms(rec):
    rf = rec["roofline"]
    return rf["t_compute"], rf["t_memory"], rf["t_collective"]


def fmt3(rec):
    c, m, x = _terms(rec)
    return f"c {c:.2f} / m {m:.2f} / x {x:.2f} s (max {max(c,m,x):.2f}s)"


def main():
    recs_single = load(V3, "singlepod")
    recs_multi = load(V3, "multipod")
    recs_opt = load(V3_OPT, "singlepod")
    n_ok_s = sum(1 for r in recs_single if r.get("status") == "ok")
    n_ok_m = sum(1 for r in recs_multi if r.get("status") == "ok")

    # dry-run ledger
    ledger_rows = []
    for r in recs_single:
        if r.get("status") != "ok":
            continue
        rm = _cell(V3, r["arch"], r["shape"], "multipod")
        ledger_rows.append(
            f"| {r['arch']} | {r['shape']} | ok ({r['compile_s']:.0f}s) | "
            f"{'ok (%.0fs)' % rm['compile_s'] if rm else 'MISSING'} | "
            f"{r['memory']['bytes']/2**30:.2f} | "
            f"{(rm['memory']['bytes']/2**30 if rm else 0):.2f} | "
            f"{len(r.get('fallbacks', []))} |"
        )
    ledger = (
        "| arch | shape | single-pod 16×16 | multi-pod 2×16×16 | mem/dev GiB (1 pod) | mem/dev GiB (2 pods) | sharding fallbacks |\n"
        "|---|---|---|---|---|---|---|\n" + "\n".join(ledger_rows)
    )

    # optimized-vs-baseline quick table for all train/prefill cells
    opt_rows = []
    for r in recs_opt:
        if r.get("status") != "ok":
            continue
        base = _cell(V3, r["arch"], r["shape"])
        if not base:
            continue
        bc, bm, bx = _terms(base)
        oc, om, ox = _terms(r)
        gain = max(bc, bm, bx) / max(max(oc, om, ox), 1e-12)
        opt_rows.append(
            f"| {r['arch']} | {r['shape']} | {max(bc,bm,bx):.2f}s | {max(oc,om,ox):.2f}s | {gain:.2f}× |"
        )
    opt_table = (
        "| arch | shape | baseline max-term | optimized max-term | gain |\n"
        "|---|---|---|---|---|\n" + "\n".join(opt_rows)
    )

    # hillclimb cells
    hc_lines = []
    cells = {
        "A — llama3-405b × train_4k (worst fraction, memory-bound)": [
            ("baseline (paper-faithful impl)", _cell(V3, "llama3-405b", "train_4k")),
            ("A1c+A2+A3 optimized", _hc("A_llama405b_train/opt_mixed_precision")),
            ("…+ attn_chunk 512 (A4, refuted)", _hc("A_llama405b_train/opt_chunk512")),
        ],
        "B — qwen3-moe-30b-a3b × train_4k (most collective-bound)": [
            ("baseline (GShard scatter dispatch)", _cell(V3, "qwen3-moe-30b-a3b", "train_4k")),
            ("B1 dense-masked MoE", _hc("B_qwen3moe_train/opt_dense_moe")),
            ("B1 on moonshot (runner-up)", _hc("B_moonshot_train/opt_dense_moe")),
            ("moonshot baseline", _cell(V3, "moonshot-v1-16b-a3b", "train_4k")),
        ],
        "C — command-r-plus-104b × decode_32k (paper-representative serving)": [
            ("baseline (bf16 serving)", _cell(V3, "command-r-plus-104b", "decode_32k")),
            ("C1 int8 weight/act dots", _hc("C_commandr_decode/opt_int8_weights")),
            ("C2 + int8 KV cache", _hc("C_commandr_decode/opt_int8_weights_kv")),
            ("C2b int8 KV only (ablation)", _hc("C_commandr_decode/opt_int8_kv_only")),
        ],
    }
    for title, rows in cells.items():
        hc_lines.append(f"\n**{title}**\n")
        hc_lines.append("| variant | compute | memory | collective | max term | mem/dev |")
        hc_lines.append("|---|---|---|---|---|---|")
        for name, rec in rows:
            if rec is None:
                hc_lines.append(f"| {name} | (missing) | | | | |")
                continue
            c, m, x = _terms(rec)
            hc_lines.append(
                f"| {name} | {c:.2f}s | {m:.2f}s | {x:.2f}s | **{max(c,m,x):.2f}s** | "
                f"{rec['memory']['bytes']/2**30:.2f} GiB |"
            )
    hc_table = "\n".join(hc_lines)

    picks = [
        ("llama3-405b", "train_4k"),
        ("qwen3-moe-30b-a3b", "train_4k"),
        ("command-r-plus-104b", "decode_32k"),
        ("qwen2.5-32b", "prefill_32k"),
        ("zamba2-7b", "long_500k"),
        ("mamba2-130m", "train_4k"),
    ]
    text = TEMPLATE.format(
        n_ok_s=n_ok_s,
        n_ok_m=n_ok_m,
        ledger=ledger,
        baseline_table=roofline_table(recs_single),
        optimized_table=opt_table,
        hillclimb=hc_table,
        coll_schedule=collective_schedule(recs_single, picks),
    )
    (ROOT / "EXPERIMENTS.md").write_text(text)
    print(f"EXPERIMENTS.md written ({n_ok_s} single-pod, {n_ok_m} multi-pod cells ok)")


TEMPLATE = """# EXPERIMENTS

Reproduction + performance report for *Memory-Immersed Collaborative
Digitization for Area-Efficient CiM Deep Learning* as a multi-pod JAX
framework. Hardware target: TPU v5e (197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI per chip); this container is CPU-only, so §Roofline terms
are derived from the compiled SPMD artifacts, not wall clocks.

## §Paper — reproduction of the paper's own claims

From `PYTHONPATH=src python -m benchmarks.run` (bench_output.txt):

| claim | paper | ours |
|---|---|---|
| in-memory ADC area vs 40nm SAR | ~25× smaller | 25.2× (207.8 µm² vs 5235.2) |
| vs 40nm Flash | ~51× smaller | 51.5× |
| energy vs SAR | ~1.4× lower | 1.41× (74.23 pJ vs 105) |
| energy vs Flash | ~13× lower | 12.8× |
| asymmetric search, 5-bit (Fig. 4c) | ~3.7 comparisons | 3.711 analytic / 3.709 measured (100k conversions) |
| DNL / INL (Fig. 6) | < 0.5 LSB | max 0.031 / 0.072 LSB @1% cap mismatch (8-seed MC) |
| MNIST accuracy at nominal point (Fig. 7c,d) | high, stable | 0.948 float → 0.895 CiM 4b/5b ADC (clean & 10 MHz) |
| accuracy collapse at high clock (Fig. 7c) | degrades | 0.895 → 0.11 @100 MHz (settling-noise model) |
| mild degradation at low VDD (Fig. 7d) | degrades slowly | 0.887 @0.55 V |
| hybrid Flash+SAR latency (Fig. 3/7b) | 1 + (B−f) cycles | exact (tests/test_adc.py) |

The asymmetric-search tree is the *exact optimal alphabetic tree* (Knuth DP),
validated against brute force; all ADC modes produce bit-identical codes to
the ideal quantizer under zero noise (tests).

## §Dry-run — 512-chip multi-pod compile ledger

Meshes: single-pod `(16,16)=(data,model)` = 256 chips; multi-pod
`(2,16,16)=(pod,data,model)` = 512 chips (pod axis shards batch + FSDP).
Every valid (arch × shape) cell lowers AND compiles on BOTH meshes:
**{n_ok_s}/32 single-pod ok, {n_ok_m}/32 multi-pod ok** (reproduce:
`python -m repro.launch.dryrun --all --both-meshes`).

Cell count: 10 archs × 4 shapes = 40 nominal; 8 `long_500k` cells are
N/A-by-assignment for pure full-attention archs (DESIGN.md §7) → 32 valid
cells, all green. `train_4k` lowers `train_step` (fwd+bwd+optimizer);
`prefill_32k` lowers `prefill`; `decode_32k`/`long_500k` lower one
`serve_step` token against a seq_len KV/state cache.

Sharding fallback column = dims that fell back to replication
(divisibility-aware rules, e.g. kv_heads=8 on a 16-way model axis — the KV
*sequence* dim takes the model axis instead: flash-decoding-style SP).

{ledger}

## §Roofline — methodology

Terms per device (per assignment):
  * compute = dot_FLOPs / 197e12;  memory = HLO_bytes / 819e9;
    collective = wire_bytes / 50e9.
  * **Measurement apparatus matters.** XLA's `cost_analysis()` counts
    while-loop bodies ONCE (verified experimentally — a 2-layer and 8-layer
    scanned model report identical flops), so all three numerators are
    re-derived from the optimized post-SPMD HLO text with loop trip-count
    multiplication (`roofline/hlo_stats.py`): dot FLOPs (MXU term;
    elementwise excluded), fusion-granularity operand+result bytes with TPU
    in-place aliasing modeled for scan stack/slice patterns, and
    bandwidth-optimal-ring wire bytes per collective
    (AG (D−1)/D·buf, AR 2(D−1)/D·buf, RS (D−1)/D·full, A2A (D−1)/D·buf,
    permute 1×buf; D = replica-group size parsed per op).
  * `MODEL/HLO flops` = 6·N_active·tokens (train) or 2·N_active·tokens
    (serve) over total HLO dot flops — the useful-compute ratio (catches
    remat/replication waste; attention flops make it <1 by construction).
  * `roofline frac` = (useful-FLOPs time) / (binding-term time): the §Perf
    score. Decode cells are intrinsically ≪1 (one token per step against the
    whole weight/cache read) — for them the memory term itself is the score.

### Baseline table (paper-faithful implementation, single-pod, all 32 cells)

{baseline_table}

### Collective schedule (per-device op executions × wire bytes per step,
representative cells; full data in results/dryrun_v3/*.json)

{coll_schedule}

Reading the table:
  * **Dense-LM train/prefill cells are memory-term bound** in this
    implementation — dominated by (a) f32 materialization of norm/attention
    internals and (b) attention score tiles round-tripping HBM; both are
    implementation artifacts the §Perf iterations attack, not physics.
  * **MoE cells are collective-bound**: the GShard scatter dispatch makes
    XLA all-gather the global token buffer per layer (2.9–6.9 TB/device/step
    wire). Iteration B1 eliminates this.
  * **Decode cells are memory-bound by weight+cache reads** — exactly the
    regime the paper's low-precision digitization addresses (iteration C).
  * SSM cells (mamba2, zamba2 long_500k) have tiny absolute terms: O(1)
    state decode — the sub-quadratic claim shows up as µs-scale terms.
  * llama3-405b fits: 5.91 GiB/device train (Adafactor states; Adam would
    need 12.7 GiB of m/v alone), 13.79 GiB decode_32k (B=128 KV cache)
    against the 16 GiB v5e HBM.

## §Perf — hypothesis → change → measure log (3 hillclimbed cells)

Cells chosen per assignment: **A** llama3-405b×train_4k (worst roofline
fraction among big-model train cells, memory-bound), **B**
qwen3-moe-30b-a3b×train_4k (most collective-bound), **C**
command-r-plus-104b×decode_32k (most representative of the paper's technique
— low-precision product-sum digitization applied to serving).

{hillclimb}

### Iteration log (chronological)

All before/after numbers below are apples-to-apples under the FINAL
measurement apparatus (parser v4: loop-aware + in-place/slice aliasing);
intermediate parser versions during the loop are noted where they changed a
conclusion. Baseline = `REPRO_LEGACY_NORM=1` + scatter MoE + bf16 serving.

* **A0 (apparatus)** — *Hypothesis*: llama's 816 s memory term (parser v1)
  is implementation traffic. *Finding*: ~45% was measurement error — scan
  stacking (`dynamic-update-slice` fusions) charged the full (L,B,S,D)
  buffer per layer where a TPU aliases in place, and slice READS of stacked
  remat residuals charged the whole stack. Parser v4 models both; llama
  baseline settles at 370.7 s. A refuted-then-fixed measurement is recorded
  because every later decision depends on it.
* **A1 (REFUTED)** — *Hypothesis*: the remaining f32[B,S,D] fusion results
  (several per layer) come from autodiff through the f32-upcast RMSNorm; a
  custom-VJP norm keeping tensors in bf16 should cut the memory term ~2×.
  *Change*: hand-fused VJP. *Measure*: memory term went UP ~55% (pre-v4
  parser: 625 → 966 s). *Lesson*: custom_vjp residuals are opaque to the
  scan-level remat — XLA saved (x, scale, inv) per layer instead of
  rematerializing, costing more than the f32 copies. Debugged forward (kept
  the intent, changed the mechanism) rather than reverting.
* **A1c (CONFIRMED)** — *Hypothesis*: the same effect is achievable inside
  autodiff if the stats reduction's backward stays in bf16: variance as a
  self-dot with f32 *output* but bf16 operands (the dot transpose emits bf16
  cotangents). *Change*: `var = einsum('...d,...d->...', x, x, f32)/D`.
* **A2 (CONFIRMED)** — attention scores/probabilities materialize in the
  compute dtype (bf16), online-softmax m/l/acc stay f32.
* **A3 (CONFIRMED)** — `jax.checkpoint` on the per-KV-chunk attention step:
  backward recomputes score tiles instead of saving the
  (n_chunks,B,S,KV,G,chunk) f32 stack (flash-attention memory behavior in
  pure XLA). **A1c+A2+A3 combined: memory term 370.7 → 317.5 s (−14%),
  roofline fraction 0.137 → 0.160.**
* **A4 (REFUTED)** — *Hypothesis*: halving attn_chunk (1024→512) reduces
  live score bytes. *Measure*: 317.5 → 328.0 s (+3%; same totals, more
  chunk-boundary traffic). Dropped.
* **B1 (CONFIRMED)** — *Hypothesis*: the scatter dispatch forces XLA to
  all-gather the global (1M, 2048) token buffer per MoE layer
  (≈6.9 TB/device/step wire); computing each device's LOCAL experts on its
  LOCAL tokens with a routing-weight mask trades ~2× expert FLOPs
  (per-expert FFN is only 768 wide) for ZERO dispatch traffic. Napkin:
  collective 278 s → psum-only ≈ 10 s; compute 2.8 → ~5 s. *Measure*:
  **collective 277.9 → 9.6 s (29×), max-term 277.9 → 17.0 s (16.3×)**;
  same change on moonshot-v1-16b-a3b: max-term 211.5 → 13.0 s (16.3×).
* **C1 (WEAKLY CONFIRMED)** — int8 weight/activation dots (s8×s8→s32 MXU —
  the paper's integer product-sums on the MXU): memory term 2.22 → 2.15 s.
  *Lesson*: at B=128 × 32k context, decode traffic is CACHE-dominated, not
  weight-dominated — the napkin missed that the (8, 32768, 8, 128)/layer
  score reads dwarf the TP-sharded weight reads.
* **C2 (CONFIRMED)** — int8 KV cache with per-(layer, kv-head) scales and
  integer score/PV dots: **memory term 2.22 → 0.57 s (3.9×), resident
  5.54 → 3.54 GiB/device**; KV-only ablation gives 0.64 s (the weight-int8
  part adds the last ~10% and removes the f32 all-gathers: collective
  0.49 → 0.14 s). Decode softmax deviation vs bf16 ≤ 5e-5; accuracy impact
  on the MNIST-CiM pipeline nil (tests).
* **D (IMPLEMENTED; measurement blocked by the container)** — fused causal
  flash-attention Pallas kernel (kernels/flash_attention.py): VMEM-resident
  online softmax, GQA head mapping, causal KV-block SKIPPING via a dynamic
  loop bound, absolute-position input so a q-SEQUENCE-sharded shard_map
  (batch over dp, S/tp query rows per model rank) masks exactly. Wired into
  the prefill path (`attn_impl="flash"`), oracle-validated to 5e-7
  (tests/test_flash_attention.py), and the full command-r-plus-104b
  prefill_32k cell COMPILES under the 512-device mesh in 3 s. The dry-run
  *byte* measurement is not comparable on this container: Pallas interpret
  mode re-fetches the (1,1,32768,128) K/V block from "HBM" on every grid
  step, where the TPU BlockSpec pipeline fetches it once per (batch, head) —
  an emulation artifact the HLO-based model cannot see through. Analytic
  projection: per layer the blocked path round-trips ~O(B·S²·h/chunk)
  score-tile bytes while flash reads q+K+V+o once — the dominant prefill
  memory contributor drops out entirely; left opt-in pending real-TPU
  measurement.
* **Stopping**: three consecutive <5% candidates on cell A (A4, chunk=2048,
  f32→bf16 loss-chunk width) hit the stop rule; the remaining A-cell memory
  term is genuine weight/activation traffic (FSDP re-gathers + remat
  recompute) whose next lever — the fused causal
  flash-attention Pallas kernel (kernels/flash_attention.py, implemented and
  oracle-validated incl. causal block SKIPPING; VMEM-resident score tiles)
  wired through shard_map, plus int8 training params — is staged next. B and
  C reached parity with their napkin floors.

### Optimized implementation — full single-pod re-sweep

The A/B/C winners are now the framework defaults (B1's dense MoE and C's
int8 serving stay config-gated: `moe_impl="dense"`, `kv_quant_int8`,
`CiMConfig(mode="int8_dot")`). Re-sweeping ALL cells with the optimized
implementation (paper-faithful baseline left column for comparison):

{optimized_table}

### Beyond-paper summary

The paper's floor (faithful CiM + memory-immersed ADC behavioral stack,
validated against every paper claim above) is separated from the beyond-paper
ceiling: mixed-precision materialization discipline (A1c/A2/A3), dispatch-free
MoE (B1), and int8 end-to-end serving (C1/C2) — the latter being the paper's
own insight (cheap low-precision digitization of product-sums) transplanted
to the MXU's native int8 path.
"""


if __name__ == "__main__":
    main()
