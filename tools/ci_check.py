"""CI gate: tier-1 tests + <30 s fabric smoke benchmarks + docs checks.

Runs the repo's tier-1 suite (ROADMAP.md), the fabric design-space sweep
(``BENCH_fabric.json``), the multi-chip shard smoke — a local 1x1-mesh
bit-exactness check, the 1/4/16-chip mesh sweep, and the shard_map
execution backend run under forced 8 host devices (subprocess; separate
``shard_map_smoke`` key), written to ``BENCH_fabric_shard.json`` — the
fused whole-model forward smoke (``repro.fabric.program`` under forced 8
host devices: bit-exact vs the per-layer loop, at most one all-gather,
measured/modeled link-latency ratio -> ``BENCH_fabric_program.json``) — the
full-transformer-block fused GRAPH smoke (``repro.fabric.graph`` under
forced 8 host devices: real ``init_transformer`` weights bit-exact vs the
per-node reference on 1x1, collective census == documented budget ->
``BENCH_fabric_graph.json``) — the scan-over-layers gate
(``compile_graph_forward(scan_layers=True)`` at n_layers=8: scanned
trace+compile strictly below unrolled, bit-exact noisy logits, census ==
per-block × n_layers + tail -> ``BENCH_fabric_scan.json``) — the
observability smoke (``repro.obs``
under forced 8 host devices: required metric names present, the fallback
counter 0 on an aligned fused batch and exactly 1 ``ragged_batch`` on a
ragged one, the JSONL trace log parse-clean, fused outputs bit-identical
with observability on vs off -> ``BENCH_obs.json``) — the continuous-
batching smoke (``repro.fabric.autotune`` under forced 8 host devices:
ragged batches served via the bucketed fused-program cache bit-exact after
pad-slicing, noisy ADC included, measured ragged-mix speedup > 5x over the
per-node loop, autotuner plan cost <= the default mesh's ->
``BENCH_fabric_autotune.json``) — the calibration
stability gate (``link_clock_calibration`` agrees across back-to-back runs
in the program/graph smokes; its magnitude is host-dependent and never
gated) — the public-api gate (every submodule ``__all__`` symbol
re-exported from ``repro.fabric.__all__`` / ``repro.obs.__all__``) — and
the docs gate: ``README.md``,
``docs/fabric.md``, and ``docs/observability.md`` must exist, every dotted
``repro.*`` reference in them must import, every ``repro.fabric`` public
symbol must be documented in ``docs/fabric.md``, and every ``repro.obs``
public symbol in ``docs/observability.md``. Exits non-zero if any stage
fails or a smoke benchmark blows its time budget.

Tier-1 additionally enforces a passed-test-count floor
(``TIER1_MIN_PASSED``) so suites cannot silently shrink.

  python tools/ci_check.py [--skip-tests] [--out BENCH_fabric.json]
                           [--shard-out BENCH_fabric_shard.json]
                           [--program-out BENCH_fabric_program.json]
                           [--graph-out BENCH_fabric_graph.json]
                           [--scan-out BENCH_fabric_scan.json]
                           [--obs-out BENCH_obs.json]
                           [--autotune-out BENCH_fabric_autotune.json]
"""

from __future__ import annotations

import argparse
import importlib
import json
import os
import re
import subprocess
import sys
import time
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
SMOKE_BUDGET_S = 30.0
# tier-1 test-count floor: suites can grow but cannot silently shrink (a
# collection error or an importorskip'd-away file drops dozens at once)
TIER1_MIN_PASSED = 295


def run_tier1() -> bool:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    proc = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q"], cwd=REPO, env=env,
        capture_output=True, text=True,
    )
    tail = proc.stdout.strip().splitlines()
    if tail:
        print(tail[-1])
    if proc.returncode != 0:
        print(proc.stdout[-4000:])
        print(proc.stderr[-2000:])
        return False
    m = re.search(r"(\d+) passed", proc.stdout)
    passed = int(m.group(1)) if m else 0
    if passed < TIER1_MIN_PASSED:
        print(f"[ci_check] FAIL: tier-1 passed only {passed} tests "
              f"< the {TIER1_MIN_PASSED} floor — did a suite stop collecting?")
        return False
    return True


def run_fabric_smoke(out: Path) -> bool:
    sys.path.insert(0, str(REPO / "src"))
    sys.path.insert(0, str(REPO))
    from benchmarks.fabric_sweep import fabric_mapping_smoke, sweep_points

    t0 = time.perf_counter()
    # same payload schema as `python -m benchmarks.fabric_sweep` (both write
    # this tracked file); shard data lives ONLY in BENCH_fabric_shard.json
    payload = {"sweep": sweep_points(), "smoke": fabric_mapping_smoke()}
    wall = time.perf_counter() - t0
    payload["wall_s"] = wall
    out.write_text(json.dumps(payload, indent=2, default=float))
    print(f"[ci_check] fabric smoke: {len(payload['sweep'])} points in "
          f"{wall:.1f}s -> {out}")
    if wall > SMOKE_BUDGET_S:
        print(f"[ci_check] FAIL: smoke took {wall:.1f}s > {SMOKE_BUDGET_S}s budget")
        return False
    ratios = [p["iso_area_throughput_ratio"] for p in payload["sweep"]
              if p["mode"] in ("pair_sar", "hybrid")]
    if not all(r >= 1.0 for r in ratios):
        print(f"[ci_check] FAIL: iso-area throughput regression: {ratios}")
        return False
    return True


def _run_forced_device_smoke(flag: str) -> dict:
    """Run a benchmarks.fabric_sweep smoke under forced 8 host devices
    (subprocess: jax pins the device count at first init, so the in-process
    smokes above cannot change it)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + str(REPO) + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    flags = env.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        env["XLA_FLAGS"] = (flags + " --xla_force_host_platform_device_count=8").strip()
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.fabric_sweep", flag],
        cwd=REPO, env=env, capture_output=True, text=True,
    )
    if proc.returncode != 0:
        return {"error": f"rc={proc.returncode}: {proc.stderr[-2000:]}"}
    try:
        return json.loads(proc.stdout)
    except json.JSONDecodeError:
        return {"error": f"unparseable output: {proc.stdout[-2000:]}"}


def run_backend_smoke() -> dict:
    return _run_forced_device_smoke("--backend-smoke")


def run_shard_smoke(out: Path) -> bool:
    """Multi-chip smoke: 1x1-mesh bit-exactness, the 1/4/16-chip sweep, and
    the shard_map execution backend under forced 8 host devices (recorded
    under its own ``shard_map_smoke`` key so the sequential trajectory in
    ``shard_sweep`` stays comparable across PRs)."""
    sys.path.insert(0, str(REPO / "src"))
    sys.path.insert(0, str(REPO))
    import jax
    import numpy as np

    from benchmarks.fabric_sweep import shard_sweep_points
    from repro.core.cim_linear import CiMConfig
    from repro.fabric import (
        ChipMeshConfig,
        FabricConfig,
        execute_matmul,
        execute_sharded_matmul,
    )

    t0 = time.perf_counter()
    fb = FabricConfig(mode="hybrid", rows=16, cols=32, n_arrays=12)
    cim = CiMConfig(mode="bitplane", a_bits=4, w_bits=4, adc_bits=5, rows=16, ste=False)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (4, 64))
    w = jax.random.normal(jax.random.fold_in(key, 1), (64, 48))
    y_shard = execute_sharded_matmul(x, w, ChipMeshConfig(fabric=fb), cim)
    y_ref = execute_matmul(x, w, fb, cim)
    bit_exact = bool((np.asarray(y_shard) == np.asarray(y_ref)).all())

    payload = {"bit_exact_1x1": bit_exact, "shard_sweep": shard_sweep_points()}
    wall = time.perf_counter() - t0
    # the backend smoke is a fresh-jax-init subprocess: budgeted separately
    # so the in-process smoke budget keeps meaning across PRs
    t0_b = time.perf_counter()
    payload["shard_map_smoke"] = run_backend_smoke()
    backend_wall = time.perf_counter() - t0_b
    payload["shard_map_smoke"]["wall_s"] = backend_wall
    payload["wall_s"] = wall
    out.write_text(json.dumps(payload, indent=2, default=float))
    print(f"[ci_check] shard smoke: {len(payload['shard_sweep'])} mesh points in "
          f"{wall:.1f}s (+{backend_wall:.1f}s backend subprocess) -> {out}")
    if not bit_exact:
        print("[ci_check] FAIL: 1x1-mesh sharded execution is not bit-exact")
        return False
    if wall > SMOKE_BUDGET_S:
        print(f"[ci_check] FAIL: shard smoke took {wall:.1f}s > {SMOKE_BUDGET_S}s budget")
        return False
    if backend_wall > 2 * SMOKE_BUDGET_S:
        print(f"[ci_check] FAIL: backend smoke took {backend_wall:.1f}s > "
              f"{2 * SMOKE_BUDGET_S}s budget")
        return False
    xchip = {p["n_chips"]: p["crosschip_bits_per_pass"] for p in payload["shard_sweep"]}
    if xchip.get(1, 1) != 0:
        print(f"[ci_check] FAIL: single-chip mesh reports cross-chip traffic: {xchip}")
        return False
    if not all(bits > 0 for chips, bits in xchip.items() if chips > 1):
        print(f"[ci_check] FAIL: multi-chip mesh reports no reduce-scatter traffic: {xchip}")
        return False
    sm = payload["shard_map_smoke"]
    if "error" in sm:
        print(f"[ci_check] FAIL: shard_map backend smoke failed: {sm['error']}")
        return False
    by_mesh = {p["mesh"]: p for p in sm.get("points", [])}
    p11, p22 = by_mesh.get("1x1"), by_mesh.get("2x2")
    if not (p11 and p22):
        print(f"[ci_check] FAIL: shard_map smoke missing mesh points: {sorted(by_mesh)}")
        return False
    if not p11.get("shard_map_available") or not p11.get("bit_exact_1x1_vs_execute"):
        print(f"[ci_check] FAIL: 1x1 shard_map not bit-exact vs execute_matmul: {p11}")
        return False
    if p22.get("backend_auto") != "shard_map" or p22.get("max_abs_diff_vs_sequential", 1.0) > 1e-4:
        print(f"[ci_check] FAIL: 2x2 shard_map diverges from sequential: {p22}")
        return False
    print(
        f"[ci_check] shard_map backend smoke: {sm['devices']} devices, 1x1 bit-exact, "
        f"2x2 maxdiff {p22['max_abs_diff_vs_sequential']:.2e}"
    )
    return True


def run_program_smoke(out: Path) -> bool:
    """Whole-model fused-forward smoke (``repro.fabric.program``) under
    forced 8 host devices: the fused shard_map program must be bit-exact vs
    the per-layer loop on a 1x1 mesh (noisy ADC included), agree to float
    tolerance on the multi-chip mesh with at most ONE all-gather in the whole
    forward, and the measured/modeled link-latency ratio is recorded to
    ``BENCH_fabric_program.json`` for cross-PR tracking."""
    t0 = time.perf_counter()
    payload = _run_forced_device_smoke("--program-smoke")
    wall = time.perf_counter() - t0
    payload["wall_s"] = wall
    out.write_text(json.dumps(payload, indent=2, default=float))
    if "error" in payload:
        print(f"[ci_check] FAIL: fused program smoke failed: {payload['error']}")
        return False
    ratio = payload.get("measured_over_modeled")
    print(
        f"[ci_check] fused program smoke: {payload['devices']} devices, "
        f"mesh {payload['mesh']}, measured/modeled link ratio "
        f"{'n/a' if ratio is None else f'{ratio:.3g}'} in {wall:.1f}s -> {out}"
    )
    if wall > 2 * SMOKE_BUDGET_S:
        print(f"[ci_check] FAIL: program smoke took {wall:.1f}s > "
              f"{2 * SMOKE_BUDGET_S}s budget")
        return False
    if not payload.get("bit_exact_1x1"):
        print("[ci_check] FAIL: fused forward is not bit-exact vs the "
              f"per-layer loop on a 1x1 mesh: {payload}")
        return False
    if payload.get("max_abs_diff_vs_per_layer", 1.0) > 1e-4:
        print("[ci_check] FAIL: fused forward diverges from the per-layer "
              f"loop: maxdiff {payload['max_abs_diff_vs_per_layer']}")
        return False
    if payload.get("backend") != "shard_map":
        print(f"[ci_check] FAIL: fused program did not resolve to shard_map "
              f"under forced devices: {payload.get('backend')} "
              f"({payload.get('problems')})")
        return False
    gathers = payload.get("collectives", {}).get("all_gather")
    if gathers is None or gathers > 1:
        print(f"[ci_check] FAIL: fused forward should contain at most one "
              f"all-gather, found {gathers}")
        return False
    return _check_calibration_stability("program", payload)


def run_graph_smoke(out: Path) -> bool:
    """Full-transformer-block fused GRAPH smoke (``repro.fabric.graph``)
    under forced 8 host devices: real ``init_transformer`` weights through
    the fused graph must be bit-exact vs the per-node reference on a 1x1
    mesh (noisy ADC included), agree to float tolerance on the multi-chip
    mesh, and the collective census must EQUAL the documented budget —
    per-sibling scatters enumerated, one trailing all-gather. Recorded to
    ``BENCH_fabric_graph.json`` for cross-PR tracking."""
    t0 = time.perf_counter()
    payload = _run_forced_device_smoke("--graph-smoke")
    wall = time.perf_counter() - t0
    payload["wall_s"] = wall
    out.write_text(json.dumps(payload, indent=2, default=float))
    if "error" in payload:
        print(f"[ci_check] FAIL: fused graph smoke failed: {payload['error']}")
        return False
    print(
        f"[ci_check] fused graph smoke: {payload['devices']} devices, mesh "
        f"{payload['mesh']}, {payload.get('n_nodes')} nodes "
        f"({payload.get('n_matmuls')} matmuls) in {wall:.1f}s -> {out}"
    )
    # 3x rather than 2x: per-row comparator noise keys (the continuous-
    # batching bit-exactness contract, repro.fabric.autotune) vmap the ADC
    # convert over batch rows, which grows the noisy trace+compile of this
    # smoke by ~30% (52s -> 69s on the 1-core CI host)
    if wall > 3 * SMOKE_BUDGET_S:
        print(f"[ci_check] FAIL: graph smoke took {wall:.1f}s > "
              f"{3 * SMOKE_BUDGET_S}s budget")
        return False
    if not payload.get("bit_exact_1x1"):
        print("[ci_check] FAIL: fused graph forward is not bit-exact vs the "
              f"per-node reference on a 1x1 mesh: {payload}")
        return False
    if payload.get("max_abs_diff_vs_reference", 1.0) > 1e-4:
        print("[ci_check] FAIL: fused graph forward diverges from the "
              f"per-node reference: maxdiff {payload['max_abs_diff_vs_reference']}")
        return False
    if payload.get("backend") != "shard_map":
        print(f"[ci_check] FAIL: fused graph did not resolve to shard_map "
              f"under forced devices: {payload.get('backend')} "
              f"({payload.get('problems')})")
        return False
    if not payload.get("budget_match"):
        print(f"[ci_check] FAIL: graph collective census != documented budget: "
              f"{payload.get('collectives')} vs {payload.get('collective_budget')}")
        return False
    gathers = payload.get("collectives", {}).get("all_gather")
    if gathers is None or gathers > 1:
        print(f"[ci_check] FAIL: fused graph should contain at most one "
              f"all-gather, found {gathers}")
        return False
    return _check_calibration_stability("graph", payload)


def run_scan_smoke(out: Path) -> bool:
    """Scan-over-layers gate (``compile_graph_forward(scan_layers=True)``)
    under forced 8 host devices: at the smoke depth (n_layers=8) the
    scanned program's trace+compile wall-clock must be STRICTLY below the
    unrolled program's, the two compiled executables must produce
    bit-identical noisy-ADC logits on a 1x1 mesh, and the scanned
    collective census must equal both the documented budget and the
    per-block census × n_layers + tail decomposition. Recorded to
    ``BENCH_fabric_scan.json`` (including ``compile_speedup``) for
    cross-PR tracking.

    Budgeted at 6x the smoke budget rather than 2x: the unrolled depth-8
    compile IS the cost this PR eliminates, and the smoke pays it once on
    purpose to document the ratio."""
    t0 = time.perf_counter()
    payload = _run_forced_device_smoke("--scan-smoke")
    wall = time.perf_counter() - t0
    payload["wall_s"] = wall
    out.write_text(json.dumps(payload, indent=2, default=float))
    if "error" in payload:
        print(f"[ci_check] FAIL: scan smoke failed: {payload['error']}")
        return False
    un = payload.get("unrolled_compile_s")
    sc = payload.get("scanned_compile_s")
    print(
        f"[ci_check] scan smoke: n_layers={payload.get('n_layers')}, "
        f"compile unrolled {un:.1f}s vs scanned {sc:.1f}s "
        f"({payload.get('compile_speedup', 0.0):.1f}x) in {wall:.1f}s -> {out}"
    )
    if wall > 6 * SMOKE_BUDGET_S:
        print(f"[ci_check] FAIL: scan smoke took {wall:.1f}s > "
              f"{6 * SMOKE_BUDGET_S}s budget")
        return False
    if not payload.get("bit_exact_1x1"):
        print("[ci_check] FAIL: scanned graph forward is not bit-exact vs "
              f"the unrolled program on a 1x1 mesh: "
              f"maxdiff {payload.get('max_abs_diff_1x1')}")
        return False
    if payload.get("backend") != "shard_map":
        print(f"[ci_check] FAIL: scanned graph did not resolve to shard_map "
              f"under forced devices: {payload.get('backend')} "
              f"({payload.get('problems')})")
        return False
    if not payload.get("budget_match"):
        print(f"[ci_check] FAIL: scanned collective census != documented "
              f"budget / per-block x n_layers: {payload.get('collectives')} "
              f"vs {payload.get('collective_budget')} vs "
              f"{payload.get('block_census_x_layers')}")
        return False
    if not (un and sc and sc < un):
        print(f"[ci_check] FAIL: scanned trace+compile ({sc}s) is not below "
              f"unrolled ({un}s) — the scan stopped paying for itself")
        return False
    return True


def _check_calibration_stability(which: str, payload: dict) -> bool:
    """Gate the named ``link_clock_calibration`` constant on *stability across
    runs*, never magnitude: the ratio of measured host-simulation seconds to
    modeled fabric-link seconds depends on the host, but back-to-back warm
    runs of the same smoke must land within a generous factor of each other
    (host-timer jitter, not a regression in the link model)."""
    runs = [r for r in payload.get("link_clock_calibration_runs", []) if r]
    if not runs:
        print(f"[ci_check] FAIL: {which} smoke reported no "
              f"link_clock_calibration runs: "
              f"{payload.get('link_clock_calibration_runs')}")
        return False
    spread = max(runs) / min(runs)
    print(f"[ci_check] {which} link_clock_calibration: "
          f"{', '.join(f'{r:.3g}' for r in runs)} (spread {spread:.2f}x)")
    if spread > 100.0:
        print(f"[ci_check] FAIL: {which} link_clock_calibration unstable "
              f"across runs: {runs} ({spread:.1f}x spread)")
        return False
    return True


# metric names the fabric/serve layers must emit under an active registry;
# the canonical table lives in docs/observability.md
REQUIRED_OBS_METRICS = (
    "fabric_conversions_total",
    "fabric_fallback_total",
    "fabric_link_bits_total",
    "fabric_matmuls_total",
    "fabric_requests_total",
)


def run_obs_smoke(out: Path) -> bool:
    """Observability smoke (``repro.obs``) under forced 8 host devices: the
    fused chain must emit every required metric name, keep the
    ``ragged_batch`` fallback counter at 0 on the aligned batch and exactly 1
    on a ragged one, write a parse-clean JSONL trace log, and produce
    bit-identical fused outputs with observability on vs off. Recorded to
    ``BENCH_obs.json`` with its own budget."""
    t0 = time.perf_counter()
    payload = _run_forced_device_smoke("--obs-smoke")
    wall = time.perf_counter() - t0
    payload["wall_s"] = wall
    out.write_text(json.dumps(payload, indent=2, default=float))
    if "error" in payload:
        print(f"[ci_check] FAIL: obs smoke failed: {payload['error']}")
        return False
    print(
        f"[ci_check] obs smoke: {payload['devices']} devices, mesh "
        f"{payload['mesh']}, {len(payload.get('metric_names', []))} metrics, "
        f"{payload.get('jsonl_records')} trace records in {wall:.1f}s -> {out}"
    )
    if wall > 2 * SMOKE_BUDGET_S:
        print(f"[ci_check] FAIL: obs smoke took {wall:.1f}s > "
              f"{2 * SMOKE_BUDGET_S}s budget")
        return False
    if payload.get("backend") != "shard_map":
        print(f"[ci_check] FAIL: obs smoke chain did not resolve to shard_map "
              f"under forced devices: {payload.get('backend')}")
        return False
    missing = [m for m in REQUIRED_OBS_METRICS
               if m not in payload.get("metric_names", [])]
    if missing:
        print(f"[ci_check] FAIL: obs smoke missing required metrics: {missing}")
        return False
    if payload.get("fallbacks_aligned") != 0:
        print(f"[ci_check] FAIL: aligned fused batch recorded fallbacks: "
              f"{payload.get('fallbacks_aligned')}")
        return False
    if payload.get("fallbacks_ragged") != 1:
        print(f"[ci_check] FAIL: ragged batch should record exactly one "
              f"ragged_batch fallback, got {payload.get('fallbacks_ragged')}")
        return False
    if not payload.get("bit_identical_with_obs"):
        print("[ci_check] FAIL: fused outputs differ with observability on "
              "vs off — instrumentation is perturbing the compiled program")
        return False
    # obs_smoke re-reads the log through read_jsonl, which raises on any
    # unparseable line — reaching a positive count IS the parse-clean gate
    if not payload.get("jsonl_records", 0) > 0:
        print(f"[ci_check] FAIL: obs smoke JSONL log is empty or unparsed: "
              f"{payload.get('jsonl_records')}")
        return False
    return True


def run_autotune_smoke(out: Path) -> bool:
    """Continuous-batching gate (``repro.fabric.autotune``) under forced 8
    host devices: a ragged batch (B=3 on the 2x2 mesh) served through the
    bucketed fused-program cache must be bit-exact to the unpadded per-node
    reference after pad-slicing — noiseless AND noisy ADC (per-row noise
    keys: pad rows must not consume draws) — the measured mixed-length
    ragged trace must beat the per-node fallback loop by > 5x, and the
    autotuner's cost-model plan must not cost more than the default mesh
    with a single max-batch bucket. Recorded to
    ``BENCH_fabric_autotune.json`` for cross-PR tracking."""
    t0 = time.perf_counter()
    payload = _run_forced_device_smoke("--autotune-smoke")
    wall = time.perf_counter() - t0
    payload["wall_s"] = wall
    out.write_text(json.dumps(payload, indent=2, default=float))
    if "error" in payload:
        print(f"[ci_check] FAIL: autotune smoke failed: {payload['error']}")
        return False
    print(
        f"[ci_check] autotune smoke: {payload['devices']} devices, mesh "
        f"{payload['mesh']}, ragged-mix speedup "
        f"{payload.get('ragged_mix_speedup', 0):.1f}x, plan "
        f"{payload.get('plan', {}).get('mesh')} buckets "
        f"{payload.get('plan', {}).get('buckets')} in {wall:.1f}s -> {out}"
    )
    # 4x rather than 2x: this smoke compiles TWO fused bucketed programs
    # (noiseless + noisy ADC) and must also warm the ~115x-slower per-node
    # fallback loop it measures the ragged-mix speedup against — that
    # baseline compile IS part of the demonstrated cost (~82s on the
    # 1-core CI host), same reasoning as the scan smoke's 6x
    if wall > 4 * SMOKE_BUDGET_S:
        print(f"[ci_check] FAIL: autotune smoke took {wall:.1f}s > "
              f"{4 * SMOKE_BUDGET_S}s budget")
        return False
    if payload.get("backend") != "shard_map":
        print(f"[ci_check] FAIL: bucketed program did not resolve to "
              f"shard_map under forced devices: {payload.get('backend')}")
        return False
    if not payload.get("bit_exact_ragged"):
        print("[ci_check] FAIL: ragged batch through the bucketed fused path "
              "is not bit-exact vs the per-node reference after pad-slicing")
        return False
    if not payload.get("bit_exact_ragged_noisy"):
        print("[ci_check] FAIL: NOISY ragged batch through the bucketed "
              "fused path is not bit-exact — pad rows are consuming "
              "noise-key draws or perturbing quantization scales")
        return False
    if payload.get("ragged_mix_speedup", 0.0) <= 5.0:
        print(f"[ci_check] FAIL: bucketed fused serving of the ragged mix "
              f"must beat the per-node loop by > 5x, got "
              f"{payload.get('ragged_mix_speedup')}")
        return False
    if payload.get("cache", {}).get("misses", 1) != 0:
        print(f"[ci_check] FAIL: every trace batch fits the bucket, yet the "
              f"cache recorded misses: {payload.get('cache')}")
        return False
    if not payload.get("plan_cost_le_default"):
        print(f"[ci_check] FAIL: autotuner plan costs more than the default "
              f"mesh: {payload.get('plan')}")
        return False
    return True


def check_public_api() -> bool:
    """Every symbol a ``repro.fabric`` / ``repro.obs`` submodule exports via
    ``__all__`` must be re-exported from the package ``__all__`` — a new
    public symbol that misses the package surface fails CI."""
    sys.path.insert(0, str(REPO / "src"))
    import repro.fabric as fabric
    import repro.obs as obs

    packages = (
        (fabric, "repro.fabric", (
            "autotune", "execute", "graph", "mapper", "pipeline", "program",
            "report", "shard", "tiles", "topology",
        )),
        (obs, "repro.obs", ("fallback", "metrics", "sinks", "trace")),
    )
    ok = True
    for pkg, pkg_name, submodules in packages:
        missing = []
        for name in submodules:
            mod = importlib.import_module(f"{pkg_name}.{name}")
            for sym in getattr(mod, "__all__", ()):
                if sym not in pkg.__all__:
                    missing.append(f"{name}.{sym}")
        if missing:
            print(f"[ci_check] FAIL: {pkg_name}.__all__ misses public "
                  "symbols: " + ", ".join(missing))
            ok = False
        else:
            print(f"[ci_check] public api: {pkg_name}.__all__ covers all "
                  f"{len(pkg.__all__)} submodule exports")
    return ok


def _resolve_dotted(ref: str) -> bool:
    """Import ``repro.a.b.C`` — module prefix via importlib, rest via getattr."""
    parts = ref.split(".")
    for split in range(len(parts), 0, -1):
        try:
            obj = importlib.import_module(".".join(parts[:split]))
        except ImportError:
            continue
        try:
            for attr in parts[split:]:
                obj = getattr(obj, attr)
        except AttributeError:
            return False
        return True
    return False


def check_docs() -> bool:
    """README.md / docs/fabric.md / docs/observability.md exist and
    reference only live symbols."""
    sys.path.insert(0, str(REPO / "src"))
    import repro.fabric as fabric
    import repro.obs as obs

    ok = True
    docs = {
        "README.md": REPO / "README.md",
        "docs/fabric.md": REPO / "docs" / "fabric.md",
        "docs/observability.md": REPO / "docs" / "observability.md",
    }
    for name, path in docs.items():
        if not path.is_file():
            print(f"[ci_check] FAIL: {name} is missing")
            ok = False
    if not ok:
        return False
    for name, path in docs.items():
        text = path.read_text()
        for ref in sorted(set(re.findall(r"\brepro(?:\.\w+)+", text))):
            if not _resolve_dotted(ref):
                print(f"[ci_check] FAIL: {name} references {ref}, which does not import")
                ok = False
    fabric_doc = docs["docs/fabric.md"].read_text()
    for sym in fabric.__all__:
        if sym not in fabric_doc:
            print(f"[ci_check] FAIL: docs/fabric.md does not document repro.fabric.{sym}")
            ok = False
    obs_doc = docs["docs/observability.md"].read_text()
    for sym in obs.__all__:
        if sym not in obs_doc:
            print(f"[ci_check] FAIL: docs/observability.md does not document "
                  f"repro.obs.{sym}")
            ok = False
    if ok:
        print("[ci_check] docs: README.md + docs/fabric.md + "
              "docs/observability.md present, all references live")
    return ok


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-tests", action="store_true")
    ap.add_argument("--out", default=str(REPO / "BENCH_fabric.json"))
    ap.add_argument("--shard-out", default=str(REPO / "BENCH_fabric_shard.json"))
    ap.add_argument("--program-out", default=str(REPO / "BENCH_fabric_program.json"))
    ap.add_argument("--graph-out", default=str(REPO / "BENCH_fabric_graph.json"))
    ap.add_argument("--scan-out", default=str(REPO / "BENCH_fabric_scan.json"))
    ap.add_argument("--obs-out", default=str(REPO / "BENCH_obs.json"))
    ap.add_argument(
        "--autotune-out", default=str(REPO / "BENCH_fabric_autotune.json")
    )
    args = ap.parse_args()

    ok = True
    if not args.skip_tests:
        print("[ci_check] running tier-1 tests ...")
        ok = run_tier1()
        print(f"[ci_check] tier-1: {'PASS' if ok else 'FAIL'}")
    if ok:
        ok = run_fabric_smoke(Path(args.out))
    if ok:
        ok = run_shard_smoke(Path(args.shard_out))
    if ok:
        ok = run_program_smoke(Path(args.program_out))
    if ok:
        ok = run_graph_smoke(Path(args.graph_out))
    if ok:
        ok = run_scan_smoke(Path(args.scan_out))
    if ok:
        ok = run_obs_smoke(Path(args.obs_out))
    if ok:
        ok = run_autotune_smoke(Path(args.autotune_out))
    if ok:
        ok = check_public_api()
    if ok:
        ok = check_docs()
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()
