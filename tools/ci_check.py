"""CI gate: tier-1 tests + the <30 s fabric smoke benchmark.

Runs the repo's tier-1 suite (ROADMAP.md), then the fabric design-space
sweep, and writes ``BENCH_fabric.json`` so successive PRs accumulate a
perf trajectory. Exits non-zero if either stage fails or the smoke
benchmark blows its time budget.

  python tools/ci_check.py [--skip-tests] [--out BENCH_fabric.json]
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
SMOKE_BUDGET_S = 30.0


def run_tier1() -> bool:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    proc = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q"], cwd=REPO, env=env
    )
    return proc.returncode == 0


def run_fabric_smoke(out: Path) -> bool:
    sys.path.insert(0, str(REPO / "src"))
    sys.path.insert(0, str(REPO))
    from benchmarks.fabric_sweep import fabric_mapping_smoke, sweep_points

    t0 = time.perf_counter()
    payload = {"sweep": sweep_points(), "smoke": fabric_mapping_smoke()}
    wall = time.perf_counter() - t0
    payload["wall_s"] = wall
    out.write_text(json.dumps(payload, indent=2, default=float))
    print(f"[ci_check] fabric smoke: {len(payload['sweep'])} points in "
          f"{wall:.1f}s -> {out}")
    if wall > SMOKE_BUDGET_S:
        print(f"[ci_check] FAIL: smoke took {wall:.1f}s > {SMOKE_BUDGET_S}s budget")
        return False
    ratios = [p["iso_area_throughput_ratio"] for p in payload["sweep"]
              if p["mode"] in ("pair_sar", "hybrid")]
    if not all(r >= 1.0 for r in ratios):
        print(f"[ci_check] FAIL: iso-area throughput regression: {ratios}")
        return False
    return True


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-tests", action="store_true")
    ap.add_argument("--out", default=str(REPO / "BENCH_fabric.json"))
    args = ap.parse_args()

    ok = True
    if not args.skip_tests:
        print("[ci_check] running tier-1 tests ...")
        ok = run_tier1()
        print(f"[ci_check] tier-1: {'PASS' if ok else 'FAIL'}")
    if ok:
        ok = run_fabric_smoke(Path(args.out))
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()
